//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full three-layer system
//! on a realistic ICU serving workload.
//!
//! * Layer 1/2: candidate scans run through the AOT JAX/Pallas kernels
//!   (`artifacts/*.hlo.txt`) on the PJRT CPU client — Python is NOT
//!   running; `make artifacts` must have been executed once.
//! * Layer 3: Rust cluster (ν=2 nodes × p=4 cores) behind the
//!   Root/Forwarder/Reducer orchestrator.
//!
//! Workload: 30k-point AHE-51-5c corpus, 200 sequential ICU queries
//! (latency-oriented, one in flight). Reports per-query latency
//! percentiles, comparisons vs PKNN, and prediction MCC vs the exhaustive
//! baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example icu_serving
//! ```

use dslsh::coordinator::{build_cluster, ClusterConfig, EngineKind};
use dslsh::experiments::{cached_corpus, eval_pknn, outer_params};
use dslsh::data::WindowSpec;
use dslsh::knn::predict::VoteConfig;
use dslsh::metrics::Confusion;
use dslsh::util::stats;

fn main() -> anyhow::Result<()> {
    let n = std::env::var("N").ok().and_then(|v| v.parse().ok()).unwrap_or(30_000);
    let n_queries = std::env::var("QUERIES").ok().and_then(|v| v.parse().ok()).unwrap_or(600);
    let (nu, p) = (2, 4);

    println!("== DSLSH ICU serving driver (three-layer AOT path) ==");
    println!("corpus: AHE-51-5c n={n}, {n_queries} queries; cluster: ν={nu} × p={p}; engine: XLA/PJRT");
    let corpus = cached_corpus(&WindowSpec::ahe_51_5c(), n, n_queries, 42)?;

    // ~10% MCC-loss operating point (paper's Table 3 configuration).
    let params = outer_params(&corpus.data, 200, 96, 42, 10);
    let t_build = std::time::Instant::now();
    let cluster = match build_cluster(
        &corpus.data,
        &params,
        &ClusterConfig::new(nu, p).with_engine(EngineKind::Xla),
    ) {
        Ok(c) => c,
        Err(e) => {
            println!("XLA engine unavailable ({e:#}); falling back to the native engine");
            build_cluster(&corpus.data, &params, &ClusterConfig::new(nu, p))?
        }
    };
    println!(
        "cluster built in {:.1}s ({} tables over {} points/node)",
        t_build.elapsed().as_secs_f64(),
        params.outer.l,
        corpus.data.len() / nu
    );

    // Serve the query stream.
    let mut latencies_ms = Vec::with_capacity(n_queries);
    let mut comparisons = Vec::with_capacity(n_queries);
    let mut confusion = Confusion::new();
    let t_serve = std::time::Instant::now();
    for i in 0..corpus.queries.len() {
        let r = cluster.query(corpus.queries.point(i));
        latencies_ms.push(r.latency_s * 1e3);
        comparisons.push(r.max_comparisons as f64);
        confusion.push(r.prediction, corpus.queries.labels[i]);
    }
    let serve_s = t_serve.elapsed().as_secs_f64();

    // Exhaustive baseline for prediction quality + comparison budget.
    println!("running PKNN baseline...");
    let pknn = eval_pknn(&corpus.data, &corpus.queries, 10, nu * p, &VoteConfig::default());

    println!();
    println!("latency  p50 {:.1} ms   p90 {:.1} ms   p99 {:.1} ms   mean {:.1} ms",
        stats::percentile(&latencies_ms, 0.50),
        stats::percentile(&latencies_ms, 0.90),
        stats::percentile(&latencies_ms, 0.99),
        stats::mean(&latencies_ms));
    println!("throughput  {:.1} queries/s (sequential — ICU latency model)",
        corpus.queries.len() as f64 / serve_s);
    let med = stats::median(&comparisons);
    let ci = stats::median_ci(&comparisons, 0.95);
    println!("comparisons  median {med:.0} [{:.0}, {:.0}]  vs PKNN {}  => speedup {:.1}×",
        ci.lo, ci.hi, pknn.comps_per_proc, pknn.comps_per_proc as f64 / med.max(1.0));
    println!("prediction  DSLSH MCC {:.3}  vs PKNN MCC {:.3}  (loss {:.3})",
        confusion.mcc(), pknn.mcc, pknn.mcc - confusion.mcc());
    println!("confusion  {confusion:?}");

    // Batched admission: the same query stream shipped in blocks through
    // the batched request path (batched hashing + reused scratch arena;
    // the register-blocked scan kernel serves the PKNN/exhaustive side).
    // Answers are identical; throughput is what moves.
    println!();
    for batch in [8usize, 32] {
        let t = std::time::Instant::now();
        let mut served = 0usize;
        let mut batched_confusion = Confusion::new();
        let mut start = 0usize;
        while start < corpus.queries.len() {
            let end = (start + batch).min(corpus.queries.len());
            let qs: Vec<&[f32]> = (start..end).map(|i| corpus.queries.point(i)).collect();
            let rs = cluster.query_batch(&qs);
            for (j, r) in rs.iter().enumerate() {
                batched_confusion.push(r.prediction, corpus.queries.labels[start + j]);
            }
            served += rs.len();
            start = end;
        }
        let dt = t.elapsed().as_secs_f64();
        assert_eq!(batched_confusion, confusion, "batched predictions must match sequential");
        println!(
            "batched throughput (batch={batch}): {:.1} queries/s ({:.2}x sequential, identical predictions)",
            served as f64 / dt,
            (served as f64 / dt) / (corpus.queries.len() as f64 / serve_s)
        );
    }
    Ok(())
}
