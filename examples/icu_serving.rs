//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full three-layer system
//! on a realistic ICU serving workload.
//!
//! * Layer 1/2: candidate scans run through the AOT JAX/Pallas kernels
//!   (`artifacts/*.hlo.txt`) on the PJRT CPU client — Python is NOT
//!   running; `make artifacts` must have been executed once.
//! * Layer 3: Rust cluster (ν=2 nodes × p=4 cores) behind the
//!   Root/Forwarder/Reducer orchestrator.
//!
//! Workload: 30k-point AHE-51-5c corpus, 600 sequential ICU queries
//! (latency-oriented, one in flight). Reports per-query latency
//! percentiles, comparisons vs PKNN, and prediction MCC vs the exhaustive
//! baseline, then batched-admission throughput, then a **mixed
//! ICU/analytics workload** through the deadline-aware admission queue's
//! priority lanes: several low-latency monitor threads (tight budgets,
//! one query in flight each, `Class::Monitor`) share the cluster with
//! bulk analytics submitters (loose budgets, deep bursts,
//! `Class::Analytics`). The cutter pops monitors first (deadline-ordered)
//! and dispatches through a pipelined window, so a monitor arriving while
//! an analytics batch is on the cluster is still cut at its deadline;
//! analytics ride leftover batch slots, protected from starvation by the
//! aging bound (see the admission module docs). Node-side budget
//! enforcement runs in `PartialResults` mode: a blown budget yields a
//! flagged table-prefix answer instead of a late complete one. The tail
//! prints per-class latency percentiles split by lane, the per-lane
//! dispatch mix (fill/deadline/aged) with budget overruns and
//! partial/shed counts, and the cut-reason mix — the primary health
//! signals for a latency-bound cluster.
//!
//! ```bash
//! make artifacts && cargo run --release --example icu_serving
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dslsh::coordinator::orchestrator::{NodeError, NodeHandle, Orchestrator};
use dslsh::coordinator::{
    build_cluster, build_live_cluster, AdmissionConfig, BudgetPolicy, Class, ClusterConfig,
    EngineKind, FailoverConfig, QuerySpec, ReplicaSet,
};
use dslsh::data::WindowSpec;
use dslsh::engine::native::NativeEngine;
use dslsh::engine::DistanceEngine;
use dslsh::experiments::{cached_corpus, eval_pknn, outer_params};
use dslsh::knn::predict::VoteConfig;
use dslsh::metrics::Confusion;
use dslsh::net::{EdgeConfig, EdgeServer};
use dslsh::node::node::{HeartbeatReply, LocalNode, NodeInfo, NodeReply};
use dslsh::slsh::SealPolicy;
use dslsh::util::stats;
use dslsh::util::threadpool::chunk_ranges;

/// A replica whose transport can be cut from the outside — the induced
/// node-kill for the failover demo. Once `dead` flips, every request
/// errors exactly like a crashed VM's closed socket would.
struct KillableNode {
    inner: LocalNode,
    dead: Arc<AtomicBool>,
}

impl KillableNode {
    fn check(&self) -> Result<(), NodeError> {
        if self.dead.load(Ordering::Relaxed) {
            Err(NodeError::new(self.inner.node_id(), "replica killed (induced fault)"))
        } else {
            Ok(())
        }
    }
}

impl NodeHandle for KillableNode {
    fn node_id(&self) -> usize {
        self.inner.node_id()
    }

    fn info(&self) -> NodeInfo {
        self.inner.info()
    }

    fn query(&mut self, q: &[f32]) -> Result<NodeReply, NodeError> {
        self.check()?;
        Ok(self.inner.query(q))
    }

    fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Result<Vec<NodeReply>, NodeError> {
        self.check()?;
        Ok(self.inner.query_batch(qs, nq))
    }

    fn heartbeat(&mut self) -> Result<HeartbeatReply, NodeError> {
        self.check()?;
        Ok(HeartbeatReply::not_live())
    }
}

/// One close-framed HTTP exchange: write the request, read to EOF (the
/// edge speaks one request per connection with `Connection: close`).
fn http(addr: std::net::SocketAddr, req: &str) -> anyhow::Result<String> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr)?;
    s.write_all(req.as_bytes())?;
    let mut reply = String::new();
    s.read_to_string(&mut reply)?;
    Ok(reply)
}

/// Status line + body of a close-framed HTTP reply, for printing.
fn status_and_body(reply: &str) -> (&str, &str) {
    let status = reply.lines().next().unwrap_or("");
    let body = reply.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    (status, body)
}

fn main() -> anyhow::Result<()> {
    let n = std::env::var("N").ok().and_then(|v| v.parse().ok()).unwrap_or(30_000);
    let n_queries = std::env::var("QUERIES").ok().and_then(|v| v.parse().ok()).unwrap_or(600);
    let (nu, p) = (2, 4);

    println!("== DSLSH ICU serving driver (three-layer AOT path) ==");
    println!("corpus: AHE-51-5c n={n}, {n_queries} queries; cluster: ν={nu} × p={p}; engine: XLA/PJRT");
    let corpus = cached_corpus(&WindowSpec::ahe_51_5c(), n, n_queries, 42)?;

    // ~10% MCC-loss operating point (paper's Table 3 configuration).
    let params = outer_params(&corpus.data, 200, 96, 42, 10);
    let t_build = std::time::Instant::now();
    let mut cluster = match build_cluster(
        &corpus.data,
        &params,
        &ClusterConfig::new(nu, p).with_engine(EngineKind::Xla),
    ) {
        Ok(c) => c,
        Err(e) => {
            println!("XLA engine unavailable ({e:#}); falling back to the native engine");
            build_cluster(&corpus.data, &params, &ClusterConfig::new(nu, p))?
        }
    };
    println!(
        "cluster built in {:.1}s ({} tables over {} points/node)",
        t_build.elapsed().as_secs_f64(),
        params.outer.l,
        corpus.data.len() / nu
    );

    // Serve the query stream.
    let mut latencies_ms = Vec::with_capacity(n_queries);
    let mut comparisons = Vec::with_capacity(n_queries);
    let mut confusion = Confusion::new();
    let t_serve = std::time::Instant::now();
    for i in 0..corpus.queries.len() {
        let r = cluster.query(corpus.queries.point(i))?;
        latencies_ms.push(r.latency_s * 1e3);
        comparisons.push(r.max_comparisons as f64);
        confusion.push(r.prediction, corpus.queries.labels[i]);
    }
    let serve_s = t_serve.elapsed().as_secs_f64();

    // Exhaustive baseline for prediction quality + comparison budget.
    println!("running PKNN baseline...");
    let pknn = eval_pknn(&corpus.data, &corpus.queries, 10, nu * p, &VoteConfig::default());

    println!();
    println!("latency  p50 {:.1} ms   p90 {:.1} ms   p99 {:.1} ms   mean {:.1} ms",
        stats::percentile(&latencies_ms, 0.50),
        stats::percentile(&latencies_ms, 0.90),
        stats::percentile(&latencies_ms, 0.99),
        stats::mean(&latencies_ms));
    println!("throughput  {:.1} queries/s (sequential — ICU latency model)",
        corpus.queries.len() as f64 / serve_s);
    let med = stats::median(&comparisons);
    let ci = stats::median_ci(&comparisons, 0.95);
    println!("comparisons  median {med:.0} [{:.0}, {:.0}]  vs PKNN {}  => speedup {:.1}×",
        ci.lo, ci.hi, pknn.comps_per_proc, pknn.comps_per_proc as f64 / med.max(1.0));
    println!("prediction  DSLSH MCC {:.3}  vs PKNN MCC {:.3}  (loss {:.3})",
        confusion.mcc(), pknn.mcc, pknn.mcc - confusion.mcc());
    println!("confusion  {confusion:?}");

    // Batched admission: the same query stream shipped in blocks through
    // the batched request path (batched hashing + reused scratch arena;
    // the register-blocked scan kernel serves the PKNN/exhaustive side).
    // Answers are identical; throughput is what moves.
    println!();
    for batch in [8usize, 32] {
        let t = std::time::Instant::now();
        let mut served = 0usize;
        let mut batched_confusion = Confusion::new();
        let mut start = 0usize;
        while start < corpus.queries.len() {
            let end = (start + batch).min(corpus.queries.len());
            let qs: Vec<&[f32]> = (start..end).map(|i| corpus.queries.point(i)).collect();
            let rs = cluster.query_batch(&qs)?;
            for (j, r) in rs.iter().enumerate() {
                batched_confusion.push(r.prediction, corpus.queries.labels[start + j]);
            }
            served += rs.len();
            start = end;
        }
        let dt = t.elapsed().as_secs_f64();
        assert_eq!(batched_confusion, confusion, "batched predictions must match sequential");
        println!(
            "batched throughput (batch={batch}): {:.1} queries/s ({:.2}x sequential, identical predictions)",
            served as f64 / dt,
            (served as f64 / dt) / (corpus.queries.len() as f64 / serve_s)
        );
    }

    // Mixed ICU/analytics admission: independent callers share one
    // cluster through the deadline-aware admission queue's priority
    // lanes. Monitors submit one query at a time under a tight budget in
    // the strict-priority lane; analytics bursts queue deep in the aged
    // lane under a loose one. Results are bit-identical to sequential
    // queries (see rust/tests/admission_parity.rs) — what moves is who
    // waits for whom.
    println!();
    println!(
        "== mixed ICU/analytics admission (max_batch=16, priority lanes, \
         budget policy: partial-results) =="
    );
    let monitors = 4usize;
    let analysts = 2usize;
    let budget_monitor = Duration::from_millis(2);
    let budget_analytics = Duration::from_millis(50);
    let q_total = corpus.queries.len();
    let per_monitor = (q_total / 2 / monitors).max(1);
    let per_analyst = (q_total / 2 / analysts).max(1);
    // Node-side budget enforcement ON: a monitor whose budget is blown
    // gets a flagged table-prefix answer at its deadline instead of a
    // complete answer arriving too late to act on.
    cluster.orchestrator.enable_admission(
        AdmissionConfig::new(corpus.data.dim, 16)
            .with_queue_cap(256)
            .with_age_bound(Duration::from_millis(20))
            .with_budget_policy(BudgetPolicy::PartialResults),
    );
    let orch = &cluster.orchestrator;
    let (monitor_lat, analytics_lat): (Vec<f64>, Vec<f64>) = std::thread::scope(|s| {
        let monitor_handles: Vec<_> = (0..monitors)
            .map(|t| {
                let corpus = &corpus;
                s.spawn(move || {
                    // Closed loop: a bedside monitor has one window in
                    // flight at a time.
                    let spec = QuerySpec::new()
                        .with_class(Class::Monitor)
                        .with_budget(budget_monitor);
                    let mut lat = Vec::with_capacity(per_monitor);
                    for j in 0..per_monitor {
                        let qi = (t * per_monitor + j) % q_total;
                        let ts = Instant::now();
                        let ticket =
                            orch.submit_spec(corpus.queries.point(qi), &spec).unwrap();
                        let _ = ticket.wait().unwrap();
                        lat.push(ts.elapsed().as_secs_f64() * 1e3);
                    }
                    lat
                })
            })
            .collect();
        let analytics_handles: Vec<_> = (0..analysts)
            .map(|t| {
                let corpus = &corpus;
                s.spawn(move || {
                    // Open-loop bursts of 16: bulk re-scoring tolerates
                    // latency, so it queues deep and waits later.
                    let spec = QuerySpec::new()
                        .with_class(Class::Analytics)
                        .with_budget(budget_analytics);
                    let mut lat = Vec::with_capacity(per_analyst);
                    let mut j = 0;
                    while j < per_analyst {
                        let burst = (per_analyst - j).min(16);
                        let ts = Instant::now();
                        let tickets: Vec<_> = (0..burst)
                            .map(|b| {
                                let qi = (q_total / 2 + t * per_analyst + j + b) % q_total;
                                orch.submit_spec(corpus.queries.point(qi), &spec).unwrap()
                            })
                            .collect();
                        for ticket in tickets {
                            let _ = ticket.wait().unwrap();
                        }
                        lat.push(ts.elapsed().as_secs_f64() * 1e3 / burst as f64);
                        j += burst;
                    }
                    lat
                })
            })
            .collect();
        (
            monitor_handles.into_iter().flat_map(|h| h.join().unwrap()).collect(),
            analytics_handles.into_iter().flat_map(|h| h.join().unwrap()).collect(),
        )
    });
    println!(
        "monitors   ({monitors} threads, budget {:>3}ms)  p50 {:.2} ms   p99 {:.2} ms",
        budget_monitor.as_millis(),
        stats::percentile(&monitor_lat, 0.50),
        stats::percentile(&monitor_lat, 0.99)
    );
    println!(
        "analytics  ({analysts} threads, budget {:>3}ms)  p50 {:.2} ms   p99 {:.2} ms  (per query, amortized over bursts)",
        budget_analytics.as_millis(),
        stats::percentile(&analytics_lat, 0.50),
        stats::percentile(&analytics_lat, 0.99)
    );
    let ad = orch.admission().unwrap().stats();
    println!(
        "admission  {} submitted, cuts: {} fill / {} deadline / {} aged, queue depth high-water {}",
        ad.submitted, ad.cuts_fill, ad.cuts_deadline, ad.cuts_aged, ad.high_water
    );
    for (name, lane) in [("monitor  ", ad.monitor), ("analytics", ad.analytics)] {
        println!(
            "  lane {name}  {} submitted, dispatched {} fill / {} deadline / {} aged, \
             {} overruns, {} partial / {} shed, depth high-water {}",
            lane.submitted,
            lane.dispatched_fill,
            lane.dispatched_deadline,
            lane.dispatched_aged,
            lane.overruns,
            lane.partials,
            lane.sheds,
            lane.high_water
        );
    }

    // Live ingest: the streaming subsystem end to end. An EMPTY live
    // cluster comes up; an ingest thread streams windows into it
    // (round-robin shard routing, deltas sealing into immutable segments
    // as they fill) while bedside monitors query THROUGH the admission
    // lanes the whole time. This is the scenario the batch-built index
    // could not serve at all — a new patient window used to mean
    // rebuilding every shard.
    println!();
    println!("== live ingest (empty cluster; monitors query under sustained ingest) ==");
    let seal_points = 4_000usize;
    let mut live = build_live_cluster(
        &outer_params(&corpus.data, 72, 48, 43, 10),
        &ClusterConfig::new(nu, p),
        SealPolicy::by_size_or_age(seal_points, Duration::from_secs(5)),
    )?;
    live.orchestrator.enable_admission(
        AdmissionConfig::new(corpus.data.dim, 16)
            .with_queue_cap(256)
            .with_budget_policy(BudgetPolicy::PartialResults),
    );
    let live_orch = &live.orchestrator;
    let ingest_batch = 64usize;
    let n_ingest = corpus.data.len().min(20_000);
    let (ingest_s, live_lat): (f64, Vec<f64>) = std::thread::scope(|s| {
        let ingester = s.spawn(|| {
            let d = &corpus.data;
            let t0 = Instant::now();
            let mut at = 0usize;
            while at < n_ingest {
                let take = ingest_batch.min(n_ingest - at);
                live_orch
                    .insert_batch_class(
                        &d.points[at * d.dim..(at + take) * d.dim],
                        &d.labels[at..at + take],
                        Class::Monitor,
                    )
                    .expect("live insert");
                at += take;
            }
            t0.elapsed().as_secs_f64()
        });
        let monitors: Vec<_> = (0..monitors)
            .map(|t| {
                let corpus = &corpus;
                s.spawn(move || {
                    let spec = QuerySpec::new()
                        .with_class(Class::Monitor)
                        .with_budget(Duration::from_millis(5));
                    let mut lat = Vec::new();
                    for j in 0..100 {
                        let qi = (t * 100 + j) % corpus.queries.len();
                        let ts = Instant::now();
                        let ticket =
                            live_orch.submit_spec(corpus.queries.point(qi), &spec).unwrap();
                        let _ = ticket.wait().unwrap();
                        lat.push(ts.elapsed().as_secs_f64() * 1e3);
                    }
                    lat
                })
            })
            .collect();
        (
            ingester.join().unwrap(),
            monitors.into_iter().flat_map(|h| h.join().unwrap()).collect(),
        )
    });
    let ing = live_orch.ingest_stats();
    let lanes = live_orch.admission().unwrap().stats();
    println!(
        "ingest     {} points in {} batches → {:.0} inserts/s, {} segments sealed (seal at {seal_points})",
        ing.points,
        ing.batches,
        ing.points as f64 / ingest_s,
        ing.sealed_segments
    );
    println!(
        "monitors   under ingest: p50 {:.2} ms   p99 {:.2} ms   ({} partial answers)",
        stats::percentile(&live_lat, 0.50),
        stats::percentile(&live_lat, 0.99),
        lanes.monitor.partials
    );
    println!(
        "  lane monitor: {} points ingested alongside {} queries (per-lane ingest attribution)",
        lanes.monitor.inserted, lanes.monitor.submitted
    );
    // The freshly ingested windows are immediately searchable: a just-
    // inserted point must be its own nearest neighbor.
    let probe = live.query(corpus.data.point(n_ingest / 2))?;
    assert!(
        probe.neighbors.first().map(|n| n.dist == 0.0).unwrap_or(false),
        "ingested point not searchable"
    );
    println!("freshness  probe of an ingested window returns itself at distance 0 ✓");

    // Fault tolerance: the same shards served by TWO replicas each behind
    // hedged, failure-aware dispatch. Mid-stream one replica of shard 0
    // is killed outright; the dispatcher fails over to its sibling, so
    // monitors keep getting COMPLETE answers (shed_nodes == 0). Killing
    // the sibling too leaves the shard unservable — queries then complete
    // within the request timeout as flagged partials instead of hanging.
    println!();
    println!("== replicated failover (2 replicas/shard; replica killed mid-run) ==");
    let failover = FailoverConfig {
        hedge_after: Duration::from_millis(5),
        request_timeout: Duration::from_millis(250),
        ..FailoverConfig::default()
    };
    let mut kill_switches: Vec<Arc<AtomicBool>> = Vec::new();
    let mut sets: Vec<ReplicaSet> = Vec::new();
    for (shard_id, range) in chunk_ranges(corpus.data.len(), nu).into_iter().enumerate() {
        let shard = Arc::new(corpus.data.shard(range.clone()));
        let replicas: Vec<Box<dyn NodeHandle>> = (0..2)
            .map(|_| {
                // Replicas share the shard slice and id base and build
                // from the same deterministic params — bit-identical
                // tables, so either replica answers for the shard.
                let engines: Vec<Box<dyn DistanceEngine>> = (0..p)
                    .map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>)
                    .collect();
                let node = LocalNode::spawn(
                    shard_id,
                    Arc::clone(&shard),
                    range.start as u64,
                    &params,
                    p,
                    engines,
                );
                let dead = Arc::new(AtomicBool::new(false));
                kill_switches.push(Arc::clone(&dead));
                Box::new(KillableNode { inner: node, dead }) as Box<dyn NodeHandle>
            })
            .collect();
        sets.push(ReplicaSet::new(shard_id, replicas));
    }
    let replicated =
        Arc::new(Orchestrator::start_replicated(sets, params.k, VoteConfig::default(), failover));
    for i in 0..200usize {
        if i == 100 {
            // Replica 0 of shard 0 dies mid-run (kill_switches is laid
            // out shard-major: [s0r0, s0r1, s1r0, s1r1]).
            kill_switches[0].store(true, Ordering::Relaxed);
            println!("   -- killed replica 0 of shard 0; queries continue --");
        }
        let r = replicated.query(corpus.queries.point(i % corpus.queries.len()))?;
        assert_eq!(r.shed_nodes, 0, "sibling replica must cover the killed one");
    }
    let fs = replicated.failover_stats();
    println!(
        "failover   200/200 complete answers; {} failovers, {} hedges ({} won), \
         {} down transitions, {} reconnect attempts",
        fs.failovers, fs.hedges, fs.hedge_wins, fs.down_transitions, fs.reconnect_attempts
    );
    // Kill the sibling too: shard 0 is now unservable, but the monitor
    // still gets an in-budget answer with the damage flagged. Span
    // collection on first, so the degraded queries land in the slow ring
    // with per-stage spans and their cause ("shed") attached.
    replicated.tracer().set_collect(true);
    kill_switches[1].store(true, Ordering::Relaxed);
    let r = replicated.query(corpus.queries.point(0))?;
    assert!(r.partial && r.shed_nodes >= 1, "dead shard must surface as a flagged partial");
    println!(
        "degraded   both replicas down: answer still in budget, shed_nodes={} partial={} ✓",
        r.shed_nodes, r.partial
    );

    // HTTP front door: the SAME degraded cluster behind the serving edge
    // (rust/src/net/edge.rs). Everything the orchestrator knows shows up
    // in status codes: liveness stays 200, readiness flips to 503 while a
    // shard has no live replica, and a query comes back as a 206 with the
    // damage flagged in the JSON — no client library required, plain
    // curl sees it all.
    println!();
    println!("== HTTP serving edge (the degraded cluster behind the JSON front door) ==");
    let edge = EdgeServer::start(
        Arc::clone(&replicated),
        std::net::TcpListener::bind("127.0.0.1:0")?,
        EdgeConfig::new(corpus.data.dim),
    )?;
    let addr = edge.addr();
    println!("listening on http://{addr}  (try: curl -s {addr}/healthz)");
    let reply = http(addr, "GET /healthz HTTP/1.1\r\nHost: icu\r\n\r\n")?;
    let (status, body) = status_and_body(&reply);
    println!("GET  /healthz   -> {status}   {body}");
    let reply = http(addr, "GET /readyz HTTP/1.1\r\nHost: icu\r\n\r\n")?;
    let (status, body) = status_and_body(&reply);
    println!("GET  /readyz    -> {status}   {body}");
    let point: Vec<String> = corpus.queries.point(0).iter().map(|v| format!("{v}")).collect();
    let q_body = format!("{{\"point\":[{}]}}", point.join(","));
    let req = format!(
        "POST /v1/query HTTP/1.1\r\nHost: icu\r\nContent-Length: {}\r\n\r\n{q_body}",
        q_body.len()
    );
    let query_reply = http(addr, &req)?;
    let (status, body) = status_and_body(&query_reply);
    println!("POST /v1/query  -> {status}");
    println!("                   {body}");
    let reply = http(addr, "GET /v1/stats HTTP/1.1\r\nHost: icu\r\n\r\n")?;
    let (status, _) = status_and_body(&reply);
    println!("GET  /v1/stats  -> {status}   ({:?})", edge.stats().query);
    assert!(status.contains("200"), "stats endpoint must serve");
    assert!(
        query_reply.starts_with("HTTP/1.1 206"),
        "degraded query must be a flagged 206 over HTTP"
    );
    println!("the shard outage is visible end to end: 503 readiness + 206 partial answers ✓");

    // The scrape surface: ONE GET exposes every counter family the
    // cluster keeps — per-endpoint edge traffic, admission queue / cut /
    // lane counters, ingest, failover, and the tracer's latency
    // histograms — in Prometheus text exposition; the slow-query ring
    // rides its own debug endpoint as JSON.
    println!();
    println!("== observability endpoints (GET /metrics, GET /v1/debug/slow) ==");
    let scrape = http(addr, "GET /metrics HTTP/1.1\r\nHost: icu\r\n\r\n")?;
    let families: Vec<&str> = scrape.lines().filter(|l| l.starts_with("# TYPE")).collect();
    println!("GET  /metrics       -> {} families, e.g.:", families.len());
    for f in families.iter().take(5) {
        println!("                       {f}");
    }
    let outage = scrape
        .lines()
        .filter(|l| l.starts_with("dslsh_failover_failovers_total")
            || l.starts_with("dslsh_failover_hedges_total")
            || l.starts_with("dslsh_replicas_down"));
    for line in outage {
        println!("                       {line}");
    }
    let reply = http(addr, "GET /v1/debug/slow HTTP/1.1\r\nHost: icu\r\n\r\n")?;
    let (status, body) = status_and_body(&reply);
    assert!(body.contains("\"slow\""), "slow-ring endpoint must serve the ring document");
    let preview: String = body.chars().take(160).collect();
    println!("GET  /v1/debug/slow -> {status}");
    println!("                       {preview}…");
    println!("every family above is also in rust/tests/observability.rs's scrape battery ✓");
    Ok(())
}
