//! Quickstart: build a DSLSH cluster over a small synthetic ABP corpus
//! and predict Acute Hypotensive Episodes for a handful of queries —
//! then the streaming path: an empty live index taking online inserts.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use dslsh::coordinator::{build_cluster, ClusterConfig, QuerySpec};
use dslsh::data::{build_corpus, CorpusConfig, WindowSpec};
use dslsh::engine::native::NativeEngine;
use dslsh::experiments::outer_params;
use dslsh::slsh::{BatchOutput, LiveIndex, LiveScratch, SealPolicy};
use dslsh::util::clock::SystemClock;

fn main() -> anyhow::Result<()> {
    // 1. Data: synthetic ABP waveforms -> beat validity -> rolling windows.
    //    (Stand-in for MIMIC-III; same geometry as the paper's AHE-51-5c.)
    println!("generating corpus (10k points, 20 out-of-sample queries)...");
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), 10_000, 20, 42));
    println!(
        "  dataset: n={}  %non-AHE={:.1}%",
        corpus.data.len(),
        corpus.data.pct_negative() * 100.0
    );

    // 2. Index parameters: outer L1 bit-sampling LSH, K=10 weighted voting.
    let params = outer_params(&corpus.data, 72, 24, 7, 10);

    // 3. Cluster: nu=2 SLSH nodes x p=2 cores, orchestrated by
    //    Root/Forwarder/Reducer threads.
    let cluster = build_cluster(&corpus.data, &params, &ClusterConfig::new(2, 2))?;
    println!(
        "cluster up: {} nodes x {} cores",
        cluster.num_nodes(),
        cluster.node_infos()[0].cores
    );

    // 4. Queries.
    let mut correct = 0;
    for i in 0..corpus.queries.len() {
        let truth = corpus.queries.labels[i];
        let r = cluster.query(corpus.queries.point(i))?;
        if r.prediction == truth {
            correct += 1;
        }
        println!(
            "query {i:2}: predicted {}  (truth {}, vote share {:.2}, {} comparisons vs {} exhaustive, {:.1} ms)",
            if r.prediction { "AHE " } else { "no-AHE" },
            if truth { "AHE " } else { "no-AHE" },
            r.positive_share,
            r.max_comparisons,
            corpus.data.len() / cluster.total_processors(),
            r.latency_s * 1e3,
        );
    }
    println!("accuracy: {correct}/{} (class imbalance makes MCC the real metric — see the exp benches)", corpus.queries.len());

    // 5. Choosing an operating point: every accuracy/latency knob rides
    //    one typed QuerySpec — `QuerySpec::default()` IS the loop above.
    //    `probes` widens the multi-probe search (each outer table visits
    //    that many buckets in margin order: more candidates, higher
    //    recall, more comparisons); `max_comparisons` is a deterministic
    //    hard cap on per-worker work (truncation is flagged `partial`);
    //    `k` trims the returned neighbor list without touching the vote.
    println!();
    println!("-- choosing an operating point (QuerySpec: probes / max_comparisons / k) --");
    let q0 = corpus.queries.point(0);
    for probes in [1u32, 2, 4, 8] {
        let r = cluster.query_spec(q0, &QuerySpec::new().with_probes(probes))?;
        println!(
            "  probes {probes}: {:>5} comparisons, {} neighbors, predicted {}",
            r.max_comparisons,
            r.neighbors.len(),
            if r.prediction { "AHE" } else { "no-AHE" },
        );
    }
    let capped = cluster
        .query_spec(q0, &QuerySpec::new().with_probes(8).with_max_comparisons(64).with_k(3))?;
    println!(
        "  probes 8 capped at 64: {} comparisons, partial={}, top-{} returned",
        capped.max_comparisons,
        capped.partial,
        capped.neighbors.len()
    );
    // Prefer a declarative dial? recall_hint maps to a probe count
    // (<=0.5 -> 1 probe, <=0.75 -> 2, <=0.9 -> 4, else 8) so callers
    // name an accuracy target instead of a bucket count.
    let hinted = cluster.query_spec(q0, &QuerySpec::new().with_recall_hint(0.9))?;
    println!("  recall_hint 0.9 (= 4 probes/table): {} comparisons", hinted.max_comparisons);
    println!("(the probes/recall/latency frontier: cargo bench --bench tradeoff)");

    // 6. Reading the telemetry: every query above already fed the
    //    cluster's always-on histograms — per-lane queue-wait/service/e2e
    //    and per-shard network/scan distributions, all in microseconds,
    //    wait-free on the hot path. Span collection is the opt-in debug
    //    tier: with it on, slow / shed / partial / hedged queries land in
    //    a bounded ring with named per-stage spans.
    println!();
    println!("-- reading the telemetry (Tracer: histograms + slow-query ring) --");
    let tracer = cluster.tracer();
    let lane = tracer.lane_hists(0); // lane 0 = "monitor", the default class
    println!(
        "  monitor-lane e2e: n={}  p50={}us  p99={}us  mean={:.1}us",
        lane.e2e_us.count,
        lane.e2e_us.p50(),
        lane.e2e_us.p99(),
        lane.e2e_us.mean()
    );
    for shard in 0..tracer.num_shards() {
        let h = tracer.shard_hists(shard);
        println!(
            "  shard {shard} scan: n={}  p50={}us  p99={}us",
            h.scan_us.count,
            h.scan_us.p50(),
            h.scan_us.p99()
        );
    }
    tracer.set_collect(true); // spans on (debug tier: a mutex per stage boundary)
    tracer.set_slow_threshold_us(0); // every query is ring-worthy, for the demo
    let _ = cluster.query(corpus.queries.point(1))?;
    for t in tracer.slow_ring() {
        println!(
            "  trace {} [{}] e2e={}us: {} stage span(s), {} node span(s)",
            t.trace_id,
            t.cause,
            t.e2e_us,
            t.spans.len(),
            t.nodes.len()
        );
    }
    tracer.set_collect(false);
    tracer.set_slow_threshold_us(dslsh::runtime::trace::DEFAULT_SLOW_THRESHOLD_US);
    println!("(served over HTTP the same numbers are one scrape away: GET /metrics)");

    // 7. Streaming: the same index as a LIVE structure — start empty,
    //    insert windows as monitors produce them, query at any point, and
    //    seal the delta into an immutable segment (by an explicit call
    //    here; in serving, by the size-or-age SealPolicy).
    println!();
    println!("-- streaming (LiveIndex: insert -> query -> seal -> query) --");
    let live = LiveIndex::new(&params, SealPolicy::by_size(8192), Arc::new(SystemClock::new()));
    // NativeEngine::new() runtime-dispatches to a 4-lane SIMD scan kernel
    // that is bit-identical to the scalar path (see engine/native.rs). An
    // 8-lane AVX2 kernel exists behind `--features wide-simd` but is
    // tolerance-grade and opt-in only (NativeEngine::with_kernel).
    let engine = NativeEngine::new();
    let (mut scratch, mut out) = (LiveScratch::new(), BatchOutput::new());
    let d = &corpus.data;
    // Stream the first 2000 windows in monitor-sized dribbles.
    for at in (0..2000).step_by(125) {
        live.insert_batch(&d.points[at * d.dim..(at + 125) * d.dim], &d.labels[at..at + 125]);
    }
    let q = corpus.queries.point(0);
    live.query_batch(&engine, q, &mut scratch, &mut out);
    println!(
        "after {} inserts: {} neighbors for query 0 ({} comparisons, delta-only)",
        live.len(),
        out.neighbors(0).len(),
        out.stats(0).comparisons
    );
    live.seal_now(); // delta -> sealed segment (inner indices built here)
    for at in (2000..3000).step_by(125) {
        live.insert_batch(&d.points[at * d.dim..(at + 125) * d.dim], &d.labels[at..at + 125]);
    }
    live.query_batch(&engine, q, &mut scratch, &mut out);
    println!(
        "after seal + {} more: {} sealed segment(s) + {} delta points, {} neighbors ({} comparisons)",
        1000,
        live.sealed_segments(),
        live.delta_len(),
        out.neighbors(0).len(),
        out.stats(0).comparisons
    );
    println!("(full streaming cluster: examples/icu_serving.rs; rates: cargo bench --bench ingest)");

    // 8. HTTP front door (zero-dependency; see rust/src/net/edge.rs and
    //    the tail of examples/icu_serving.rs for a running server). Any
    //    orchestrator can be served over plain HTTP/1.1 + JSON:
    //
    //        use dslsh::net::{EdgeConfig, EdgeServer};
    //        let listener = std::net::TcpListener::bind("127.0.0.1:8080")?;
    //        let edge = EdgeServer::start(orch, listener, EdgeConfig::new(dim))?;
    //
    //    and then exercised from a shell — one request per connection,
    //    responses close-framed:
    //
    //        curl -s localhost:8080/healthz
    //        curl -s localhost:8080/readyz          # 503 while a shard has no live replica
    //        curl -s localhost:8080/v1/stats        # edge/admission/ingest/failover + per-lane probes/EWMA
    //        curl -s localhost:8080/metrics         # EVERY family, Prometheus text exposition
    //        curl -s localhost:8080/v1/debug/slow   # the slow-query ring as JSON
    //        curl -s -X POST localhost:8080/v1/query \
    //             -d '{"point":[0.1,0.2, ...], "budget_us":2000, "policy":"partial", "class":"monitor"}'
    //        curl -s -X POST localhost:8080/v1/query \      # the full QuerySpec over JSON
    //             -d '{"point":[0.1,0.2, ...], "probes":4, "max_comparisons":5000, "k":3}'
    //        curl -s -X POST localhost:8080/v1/query \      # declarative accuracy dial
    //             -d '{"point":[0.1,0.2, ...], "recall_hint":0.9}'
    //        curl -s -X POST localhost:8080/v1/insert \
    //             -d '{"points":[[0.1,0.2, ...]], "labels":[true]}'
    //
    //    A blown budget comes back as `206 Partial Content` with
    //    `"partial":true`; a full admission queue as `429` with a
    //    `Retry-After` header; malformed input as a typed 4xx JSON error
    //    (see rust/tests/http_edge.rs for the full hostile-input battery).
    Ok(())
}
