//! Speed-vs-quality sweep (miniature Figure 3/4): how (m, L) and the
//! stratified inner layer move the comparisons/MCC trade-off.
//!
//! ```bash
//! cargo run --release --example tradeoff_sweep
//! ```

use dslsh::coordinator::{build_cluster, ClusterConfig};
use dslsh::data::WindowSpec;
use dslsh::experiments::report::Table;
use dslsh::experiments::{cached_corpus, eval_cluster, eval_pknn, outer_params};
use dslsh::knn::predict::VoteConfig;
use dslsh::slsh::InnerParams;

fn main() -> anyhow::Result<()> {
    let corpus = cached_corpus(&WindowSpec::ahe_301_30c(), 20_000, 80, 42)?;
    let cfg = ClusterConfig::new(2, 2);
    let pknn = eval_pknn(&corpus.data, &corpus.queries, 10, 4, &VoteConfig::default());
    println!("PKNN: {} comparisons/processor, MCC = {:.3}\n", pknn.comps_per_proc, pknn.mcc);

    let mut table = Table::new(
        "Trade-off sweep (mini Fig 3/4)",
        &["config", "median comps", "speedup", "MCC", "MCC loss"],
    );
    // Outer sweep: more bits (m) => fewer candidates, lower MCC;
    // more tables (L) => the reverse.
    for (m, l) in [(60usize, 24usize), (90, 24), (120, 24), (90, 48), (90, 96)] {
        let params = outer_params(&corpus.data, m, l, 7, 10);
        let cluster = build_cluster(&corpus.data, &params, &cfg)?;
        let run = eval_cluster(&cluster, &corpus);
        table.row(vec![
            format!("LSH m={m} L={l}"),
            format!("{:.0}", run.median_comps),
            format!("{:.1}", pknn.comps_per_proc as f64 / run.median_comps.max(1.0)),
            format!("{:.3}", run.mcc),
            format!("{:.3}", pknn.mcc - run.mcc),
        ]);
    }
    // Stratified inner layer on the coarsest outer point.
    for (m_in, l_in) in [(40usize, 20usize), (90, 20)] {
        let mut params = outer_params(&corpus.data, 60, 24, 7, 10);
        params.inner = Some(InnerParams { m: m_in, l: l_in, alpha: 0.01, seed: 99 });
        let cluster = build_cluster(&corpus.data, &params, &cfg)?;
        let run = eval_cluster(&cluster, &corpus);
        table.row(vec![
            format!("SLSH m_in={m_in} L_in={l_in} (outer 60/24)"),
            format!("{:.0}", run.median_comps),
            format!("{:.1}", pknn.comps_per_proc as f64 / run.median_comps.max(1.0)),
            format!("{:.3}", run.mcc),
            format!("{:.3}", pknn.mcc - run.mcc),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
