//! Strong-scaling demo: watch the paper's speed metric (max comparisons
//! per processor) fall as nodes are added, while predictions stay put.
//!
//! A miniature of Tables 2/3; run the `table2_scaling`/`table3_scaling`
//! benches for the full reproduction.
//!
//! ```bash
//! cargo run --release --example scaling_demo
//! ```

use dslsh::coordinator::{build_cluster, ClusterConfig};
use dslsh::data::WindowSpec;
use dslsh::experiments::report::Table;
use dslsh::experiments::{cached_corpus, eval_cluster, outer_params};

fn main() -> anyhow::Result<()> {
    let corpus = cached_corpus(&WindowSpec::ahe_51_5c(), 24_000, 80, 42)?;
    let params = outer_params(&corpus.data, 100, 48, 9, 10);
    let p = 4;

    let mut table = Table::new(
        format!("Strong scaling demo — n = {}, p = {p}", corpus.data.len()),
        &["ν", "pν", "median max-comps", "S_base", "PKNN n/(pν)", "ratio", "MCC"],
    );
    let mut base: Option<f64> = None;
    for nu in [1usize, 2, 3, 4, 5] {
        let cluster = build_cluster(&corpus.data, &params, &ClusterConfig::new(nu, p))?;
        let run = eval_cluster(&cluster, &corpus);
        let procs = nu * p;
        let pknn = (corpus.data.len() as f64 / procs as f64).ceil();
        let s = match base {
            None => {
                base = Some(run.median_comps);
                1.0
            }
            Some(b) => b / run.median_comps.max(1.0),
        };
        table.row(vec![
            nu.to_string(),
            procs.to_string(),
            format!("{:.0}", run.median_comps),
            format!("{s:.2}"),
            format!("{pknn:.0}"),
            format!("{:.1}", pknn / run.median_comps.max(1.0)),
            format!("{:.3}", run.mcc),
        ]);
    }
    println!("{}", table.render());
    println!("(near-linear S_base and constant MCC = the paper's §4.2 claim)");
    Ok(())
}
