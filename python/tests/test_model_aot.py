"""Layer-2 model graphs + AOT lowering checks.

Verifies (a) the padded model wrappers agree with unpadded references,
(b) every catalog entry lowers to parseable HLO text, (c) lowering is
deterministic (stable artifact hashing for `make artifacts` no-op logic).
"""

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def rng(seed=0):
    return np.random.default_rng(seed)


@pytest.mark.parametrize("bc", [256, 2048])
def test_model_l1_matches_ref_with_d30(bc):
    r = rng(bc)
    fn, _ = model.make_l1_scan(1, bc, 30)
    q = r.uniform(20, 180, size=(1, 30)).astype(np.float32)
    c = r.uniform(20, 180, size=(bc, 30)).astype(np.float32)
    mask = np.ones(bc, dtype=np.float32)
    mask[bc // 2 :] = 0.0
    (got,) = fn(q, c, mask)
    want = np.asarray(ref.l1_scan_ref(q, c, mask))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-3)


def test_model_cosine_padding_is_harmless():
    # Zero-padding d 30->32 must not change cosine distances.
    r = rng(7)
    fn, _ = model.make_cosine_scan(1, 256, 30)
    q = r.normal(size=(1, 30)).astype(np.float32)
    c = r.normal(size=(256, 30)).astype(np.float32)
    mask = np.ones(256, dtype=np.float32)
    (got,) = fn(q, c, mask)
    want = np.asarray(ref.cosine_scan_ref(q, c, mask))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_model_hash_outer_matches_ref():
    r = rng(9)
    l, m, d = 12, 25, 30
    fn, _ = model.make_hash_outer(l, m, d)
    x = r.uniform(0, 100, size=(d,)).astype(np.float32)
    coords = r.integers(0, d, size=(l, m)).astype(np.int32)
    thr = r.uniform(0, 100, size=(l, m)).astype(np.float32)
    (got,) = fn(x, coords, thr)
    want = np.asarray(ref.hash_bits_ref(x, coords, thr))
    np.testing.assert_array_equal(np.asarray(got), want)


def test_catalog_lowers_to_hlo_text():
    catalog = aot.build_catalog(dim=30, ladder=(256,))
    assert set(k.split("_b")[0] for k in catalog if "_b" in k) == {
        "l1_scan",
        "cosine_scan",
    }
    for name, (fn, args, meta) in catalog.items():
        text = aot.to_hlo_text(fn, args)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: missing entry computation"
        # The tuple-return convention the Rust loader unwraps.
        assert "tuple" in text.lower(), f"{name}: expected tuple return"


def test_lowering_is_deterministic():
    fn, args = model.make_l1_scan(1, 256, 30)
    a = aot.to_hlo_text(fn, args)
    fn2, args2 = model.make_l1_scan(1, 256, 30)
    b = aot.to_hlo_text(fn2, args2)
    assert a == b


def test_batch_ladder_is_block_aligned():
    from compile.kernels.l1_scan import BLOCK_C

    for bc in model.BATCH_LADDER:
        assert bc % BLOCK_C == 0
    assert model.BATCH_LADDER == tuple(sorted(model.BATCH_LADDER))
