"""Kernel-vs-oracle correctness: the CORE signal for Layer 1.

Hypothesis sweeps shapes and values; every Pallas kernel (interpret mode)
must match the pure-jnp reference to float32 tolerance.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.cosine_scan import cosine_scan, cosine_scan_whole
from compile.kernels.hash_bits import projection_bits, threshold_bits
from compile.kernels.l1_scan import l1_scan, l1_scan_whole

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=40, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Shape/value sweeps (hypothesis)
# ---------------------------------------------------------------------------


@given(
    bq=st.integers(1, 4),
    bc=st.integers(1, 64),
    d=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    pad_frac=st.floats(0.0, 0.9),
)
def test_l1_whole_matches_ref(bq, bc, d, seed, pad_frac):
    r = rng(seed)
    q = r.uniform(20, 180, size=(bq, d)).astype(np.float32)
    c = r.uniform(20, 180, size=(bc, d)).astype(np.float32)
    mask = (r.uniform(size=bc) >= pad_frac).astype(np.float32)
    got = np.asarray(l1_scan_whole(q, c, mask))
    want = np.asarray(ref.l1_scan_ref(q, c, mask))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@given(
    bq=st.integers(1, 3),
    bc=st.integers(1, 48),
    d=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_cosine_whole_matches_ref(bq, bc, d, seed):
    r = rng(seed)
    q = r.normal(size=(bq, d)).astype(np.float32)
    c = r.normal(size=(bc, d)).astype(np.float32)
    mask = np.ones(bc, dtype=np.float32)
    got = np.asarray(cosine_scan_whole(q, c, mask))
    want = np.asarray(ref.cosine_scan_ref(q, c, mask))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    l=st.integers(1, 16),
    m=st.integers(1, 64),
    d=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_hash_bits_match_ref(l, m, d, seed):
    r = rng(seed)
    x = r.uniform(0, 100, size=(d,)).astype(np.float32)
    coords = r.integers(0, d, size=(l, m)).astype(np.int32)
    thr = r.uniform(0, 100, size=(l, m)).astype(np.float32)
    gathered = np.take(x, coords)
    got = np.asarray(threshold_bits(gathered, thr))
    want = np.asarray(ref.hash_bits_ref(x, coords, thr))
    np.testing.assert_array_equal(got, want)
    assert set(np.unique(got)).issubset({0.0, 1.0})


@given(
    l=st.integers(1, 6),
    m=st.integers(1, 32),
    d=st.integers(2, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_projection_bits_match_ref(l, m, d, seed):
    r = rng(seed)
    x = r.normal(size=(d,)).astype(np.float32)
    dirs = r.normal(size=(l, m, d)).astype(np.float32)
    got = np.asarray(projection_bits(x, dirs))
    want = np.asarray(ref.projection_bits_ref(x, dirs))
    # Sign boundaries can flip under f32 reassociation; allow a tiny
    # disagreement rate only where |dot| is below tolerance.
    dots = np.einsum("lmd,d->lm", dirs, x)
    decided = np.abs(dots) > 1e-4
    np.testing.assert_array_equal(got[decided], want[decided])


# ---------------------------------------------------------------------------
# Tiled (production BlockSpec) kernels vs whole-array variants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bc", [128, 256, 512])
@pytest.mark.parametrize("bq", [1, 4])
def test_l1_tiled_equals_whole(bc, bq):
    r = rng(bc * 7 + bq)
    d = 32
    q = r.uniform(20, 180, size=(bq, d)).astype(np.float32)
    c = r.uniform(20, 180, size=(bc, d)).astype(np.float32)
    mask = np.ones(bc, dtype=np.float32)
    mask[-5:] = 0.0
    tiled = np.asarray(l1_scan(q, c, mask))
    whole = np.asarray(l1_scan_whole(q, c, mask))
    np.testing.assert_allclose(tiled, whole, rtol=1e-6, atol=1e-3)


@pytest.mark.parametrize("bc", [128, 384])
def test_cosine_tiled_equals_whole(bc):
    r = rng(bc)
    d = 32
    q = r.normal(size=(1, d)).astype(np.float32)
    c = r.normal(size=(bc, d)).astype(np.float32)
    mask = np.ones(bc, dtype=np.float32)
    tiled = np.asarray(cosine_scan(q, c, mask))
    whole = np.asarray(cosine_scan_whole(q, c, mask))
    np.testing.assert_allclose(tiled, whole, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Semantics pinned by the Rust side
# ---------------------------------------------------------------------------


def test_padding_rows_get_pad_dist():
    q = np.zeros((1, 30), dtype=np.float32)
    c = np.zeros((4, 30), dtype=np.float32)
    mask = np.array([1, 0, 1, 0], dtype=np.float32)
    out = np.asarray(l1_scan_whole(q, c, mask))[0]
    assert out[0] == 0.0 and out[2] == 0.0
    assert out[1] == ref.PAD_DIST and out[3] == ref.PAD_DIST


def test_cosine_zero_norm_is_distance_one():
    q = np.ones((1, 8), dtype=np.float32)
    c = np.zeros((2, 8), dtype=np.float32)
    mask = np.ones(2, dtype=np.float32)
    out = np.asarray(cosine_scan_whole(q, c, mask))[0]
    np.testing.assert_allclose(out, [1.0, 1.0], atol=1e-6)


def test_l1_identity_is_zero():
    r = rng(1)
    x = r.uniform(size=(1, 30)).astype(np.float32)
    out = np.asarray(l1_scan_whole(x, x, np.ones(1, dtype=np.float32)))
    np.testing.assert_allclose(out, [[0.0]], atol=1e-6)
