"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis) and double as readable specifications. Padding semantics:
``mask[i] == 0`` marks candidate row ``i`` as padding; its distance is
forced to ``PAD_DIST`` so the Rust top-K reducer can never select it.
"""

import jax.numpy as jnp

# Distance assigned to padding rows — far beyond any real L1 distance on
# physiological data (max possible: 30 coords * ~160 mmHg = 4.8e3) and any
# cosine distance (max 2).
PAD_DIST = 1e9


def l1_scan_ref(q, c, mask):
    """L1 distances between each query row and each candidate row.

    Args:
      q: (bq, d) float32 queries.
      c: (bc, d) float32 candidates.
      mask: (bc,) float32, 1.0 = real candidate, 0.0 = padding.

    Returns:
      (bq, bc) float32 distances, PAD_DIST where mask == 0.
    """
    d = jnp.sum(jnp.abs(q[:, None, :] - c[None, :, :]), axis=-1)
    return d * mask[None, :] + (1.0 - mask[None, :]) * PAD_DIST


def cosine_scan_ref(q, c, mask, eps=1e-12):
    """Cosine distances (1 - cos) with zero-norm rows at distance 1."""
    qn = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True))  # (bq, 1)
    cn = jnp.sqrt(jnp.sum(c * c, axis=-1, keepdims=True))  # (bc, 1)
    dot = q @ c.T  # (bq, bc)
    denom = qn * cn.T
    cos = jnp.where(denom > eps, dot / jnp.maximum(denom, eps), 0.0)
    dist = 1.0 - cos
    return dist * mask[None, :] + (1.0 - mask[None, :]) * PAD_DIST


def hash_bits_ref(x, coords, thresholds):
    """Bit-sampling hash bits for the outer L1 layer.

    Args:
      x: (d,) float32 point.
      coords: (L, m) int32 sampled coordinates.
      thresholds: (L, m) float32 sampled thresholds.

    Returns:
      (L, m) float32 in {0, 1}: x[coords] >= thresholds.
    """
    gathered = jnp.take(x, coords, axis=0)
    return (gathered >= thresholds).astype(jnp.float32)


def projection_bits_ref(x, dirs):
    """Sign-random-projection bits for the inner cosine layer.

    Args:
      x: (d,) float32 point.
      dirs: (L, m, d) float32 Gaussian directions.

    Returns:
      (L, m) float32 in {0, 1}: sign(dirs @ x) >= 0.
    """
    dots = jnp.einsum("lmd,d->lm", dirs, x)
    return (dots >= 0.0).astype(jnp.float32)
