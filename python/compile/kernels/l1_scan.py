"""Pallas kernel: batched L1 candidate scan (the paper's hot spot).

"For large datasets, the linear search over the candidates is the
bottleneck for LSH" (paper §2) — this kernel is that linear search: L1
distances from a (small) block of queries to a tile of gathered candidate
rows, with a padding mask.

TPU-style structure (DESIGN.md §Hardware-Adaptation):
  * the query block (bq × d) stays resident in VMEM across the whole grid;
  * candidates stream through VMEM in (BLOCK_C × d) tiles via BlockSpec —
    the HBM→VMEM pipeline a CUDA implementation would express with
    threadblocks;
  * d is padded to 32 (= 4 VPU sublanes of 8) by the caller (model.py), so
    the reduction axis vectorizes cleanly; padding coordinates are zero in
    both operands and cancel in |q - c|;
  * the mask is applied in-register — no separate pass over the output.

MUST be lowered with ``interpret=True``: this image runs the CPU PJRT
plugin, which cannot execute Mosaic custom-calls (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PAD_DIST

# Candidate rows per VMEM tile. 128 rows × 32 f32 = 16 KiB per tile —
# with double buffering and the output tile this stays ≪ 1 MiB of VMEM.
BLOCK_C = 128


def _l1_kernel(q_ref, c_ref, mask_ref, o_ref):
    """One grid step: distances from all queries to one candidate tile."""
    q = q_ref[...]  # (bq, d)   resident
    c = c_ref[...]  # (blk, d)  streamed
    mask = mask_ref[...]  # (blk,)
    # |q - c| summed over d: (bq, 1, d) - (1, blk, d) -> (bq, blk).
    dist = jnp.sum(jnp.abs(q[:, None, :] - c[None, :, :]), axis=-1)
    o_ref[...] = dist * mask[None, :] + (1.0 - mask[None, :]) * PAD_DIST


@functools.partial(jax.jit, static_argnames=("block_c",))
def l1_scan(q, c, mask, *, block_c=BLOCK_C):
    """L1 distances (bq, bc) between queries and masked candidates.

    ``bc`` must be a multiple of ``block_c`` (model.py guarantees this by
    construction of the artifact batch ladder).
    """
    bq, d = q.shape
    bc, d2 = c.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert bc % block_c == 0, f"bc={bc} not a multiple of {block_c}"
    grid = (bc // block_c,)
    return pl.pallas_call(
        _l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (0, 0)),  # query: resident
            pl.BlockSpec((block_c, d), lambda i: (i, 0)),  # candidates: streamed
            pl.BlockSpec((block_c,), lambda i: (i,)),  # mask
        ],
        out_specs=pl.BlockSpec((bq, block_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((bq, bc), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, c, mask)


def l1_scan_whole(q, c, mask):
    """Single-tile variant (grid=1) accepting any (bq, bc, d) — used by the
    hypothesis sweep to exercise odd shapes."""
    bq, _ = q.shape
    bc, _ = c.shape
    return pl.pallas_call(
        _l1_kernel,
        out_shape=jax.ShapeDtypeStruct((bq, bc), jnp.float32),
        interpret=True,
    )(q, c, mask)
