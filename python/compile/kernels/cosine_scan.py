"""Pallas kernel: batched cosine-distance candidate scan.

Companion to l1_scan.py for the inner (cosine) metric — same tiling
scheme, but the per-tile math is a dot product against the resident query
block plus row-norm normalization, i.e. an MXU-shaped (bq × d) @ (d × blk)
contraction on real TPU hardware.

Zero-norm rows (all-zero padding or degenerate points) are defined to be
at distance 1, matching the Rust native engine and ref.py; the mask then
overrides padding rows to PAD_DIST.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PAD_DIST

BLOCK_C = 128
_EPS = 1e-12


def _cosine_kernel(q_ref, c_ref, mask_ref, o_ref):
    q = q_ref[...]  # (bq, d)
    c = c_ref[...]  # (blk, d)
    mask = mask_ref[...]  # (blk,)
    dot = q @ c.T  # (bq, blk) — MXU contraction on TPU
    qn = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True))  # (bq, 1)
    cn = jnp.sqrt(jnp.sum(c * c, axis=-1))[None, :]  # (1, blk)
    denom = qn * cn
    cos = jnp.where(denom > _EPS, dot / jnp.maximum(denom, _EPS), 0.0)
    dist = 1.0 - cos
    o_ref[...] = dist * mask[None, :] + (1.0 - mask[None, :]) * PAD_DIST


@functools.partial(jax.jit, static_argnames=("block_c",))
def cosine_scan(q, c, mask, *, block_c=BLOCK_C):
    """Cosine distances (bq, bc); bc must be a multiple of block_c."""
    bq, d = q.shape
    bc, d2 = c.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert bc % block_c == 0, f"bc={bc} not a multiple of {block_c}"
    grid = (bc // block_c,)
    return pl.pallas_call(
        _cosine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (0, 0)),
            pl.BlockSpec((block_c, d), lambda i: (i, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bq, block_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((bq, bc), jnp.float32),
        interpret=True,
    )(q, c, mask)


def cosine_scan_whole(q, c, mask):
    """Single-tile variant for arbitrary shapes (hypothesis sweep)."""
    bq, _ = q.shape
    bc, _ = c.shape
    return pl.pallas_call(
        _cosine_kernel,
        out_shape=jax.ShapeDtypeStruct((bq, bc), jnp.float32),
        interpret=True,
    )(q, c, mask)
