"""Pallas kernel: query hashing for all L outer tables in one shot.

Bit-sampling (Gionis et al.) evaluates, for each of the L·m sampled
(coordinate, threshold) pairs, the predicate x[coord] >= threshold. The
kernel receives the point broadcast-gathered by coordinate (model.py does
the gather with jnp.take inside the same jitted graph, so it fuses into
this HLO module) and emits the L×m bit matrix; the Rust side packs bits
into table keys.

The tiny arithmetic intensity makes this VPU work; it exists to move the
*entire* per-query hash computation into one AOT artifact so the request
path stays Python-free while exercising a second kernel shape.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_kernel(gathered_ref, thr_ref, o_ref):
    o_ref[...] = (gathered_ref[...] >= thr_ref[...]).astype(jnp.float32)


def threshold_bits(gathered, thresholds):
    """(L, m) bits = gathered >= thresholds, as float32 {0,1}."""
    return pl.pallas_call(
        _hash_kernel,
        out_shape=jax.ShapeDtypeStruct(gathered.shape, jnp.float32),
        interpret=True,
    )(gathered, thresholds)


def _proj_kernel(x_ref, dirs_ref, o_ref):
    x = x_ref[...]  # (d,)
    dirs = dirs_ref[...][0]  # block (1, m, d) -> (m, d): one table
    dots = dirs @ x  # (m,)
    o_ref[...] = (dots >= 0.0).astype(jnp.float32)[None, :]


def projection_bits(x, dirs):
    """(L, m) sign-projection bits; dirs is (L, m, d), gridded over L."""
    l, m, d = dirs.shape
    return pl.pallas_call(
        _proj_kernel,
        grid=(l,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1, m, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, m), jnp.float32),
        interpret=True,
    )(x, dirs)
