"""AOT lowering: JAX/Pallas graphs -> artifacts/*.hlo.txt + manifest.json.

HLO **text** is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
`artifacts` target). Python runs ONCE here; the Rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# Default hash-artifact configuration: the paper's SLSH onset
# (m_out = 125, L_out = 120). Other configs fall back to native hashing.
ONSET_L, ONSET_M = 120, 125
DIM = 30


def to_hlo_text(fn, example_args):
    """Lower a jitted function to XLA HLO text via stablehlo."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_catalog(dim=DIM, bq=1, ladder=model.BATCH_LADDER):
    """All artifacts to emit: name -> (fn, example_args, meta)."""
    catalog = {}
    for bc in ladder:
        fn, args = model.make_l1_scan(bq, bc, dim)
        catalog[f"l1_scan_b{bc}"] = (fn, args, {"kind": "l1_scan", "bq": bq, "bc": bc, "d": dim})
        fn, args = model.make_cosine_scan(bq, bc, dim)
        catalog[f"cosine_scan_b{bc}"] = (
            fn,
            args,
            {"kind": "cosine_scan", "bq": bq, "bc": bc, "d": dim},
        )
    fn, args = model.make_hash_outer(ONSET_L, ONSET_M, dim)
    catalog[f"hash_outer_l{ONSET_L}_m{ONSET_M}"] = (
        fn,
        args,
        {"kind": "hash_outer", "l": ONSET_L, "m": ONSET_M, "d": dim},
    )
    return catalog


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--dim", type=int, default=DIM)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"dim": args.dim, "bq": 1, "artifacts": {}}
    catalog = build_catalog(dim=args.dim)
    for name, (fn, example_args, meta) in catalog.items():
        text = to_hlo_text(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["file"] = f"{name}.hlo.txt"
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        meta["bytes"] = len(text)
        manifest["artifacts"][name] = meta
        print(f"  {name}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(catalog)} artifacts to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
