"""Layer 2: the JAX compute graphs DSLSH ships as AOT artifacts.

Each public ``make_*`` returns a jit-able function over fixed example
shapes; aot.py lowers them once to HLO text which the Rust runtime loads
via PJRT. Python never runs on the request path.

Design notes:
  * Points are d=30; graphs pad the feature axis to D_PAD=32 *inside* the
    traced function (zero padding cancels in both metrics), so the wire
    interface keeps the paper's natural shape.
  * Candidate batches come in a fixed ladder of sizes (one compiled
    executable per size); the Rust engine pads the last tile with
    mask=0 rows which the kernels force to PAD_DIST.
"""

import jax
import jax.numpy as jnp

from .kernels.cosine_scan import cosine_scan
from .kernels.hash_bits import threshold_bits
from .kernels.l1_scan import l1_scan

# Feature padding target: 32 f32 = one 128-byte VPU-friendly row.
D_PAD = 32

# Candidate-batch ladder. Multiples of the kernels' BLOCK_C=128. Perf pass
# (EXPERIMENTS.md §Perf): the original (256, 2048, 16384) ladder hit a
# pathological 58 ms/call on the 16384-row executable (interpret-mode
# Pallas grid overhead scales with tile count); capping at 2048 and
# chunking larger scans cut large-batch cost ~20x.
BATCH_LADDER = (256, 1024, 2048)


def _pad_d(x):
    """Zero-pad the trailing feature axis to D_PAD."""
    d = x.shape[-1]
    if d == D_PAD:
        return x
    assert d < D_PAD, f"d={d} exceeds D_PAD={D_PAD}"
    widths = [(0, 0)] * (x.ndim - 1) + [(0, D_PAD - d)]
    return jnp.pad(x, widths)


def make_l1_scan(bq, bc, d):
    """(q (bq,d), c (bc,d), mask (bc,)) -> (bq, bc) L1 distances."""

    def fn(q, c, mask):
        return (l1_scan(_pad_d(q), _pad_d(c), mask),)

    return fn, (
        jax.ShapeDtypeStruct((bq, d), jnp.float32),
        jax.ShapeDtypeStruct((bc, d), jnp.float32),
        jax.ShapeDtypeStruct((bc,), jnp.float32),
    )


def make_cosine_scan(bq, bc, d):
    """(q (bq,d), c (bc,d), mask (bc,)) -> (bq, bc) cosine distances."""

    def fn(q, c, mask):
        return (cosine_scan(_pad_d(q), _pad_d(c), mask),)

    return fn, (
        jax.ShapeDtypeStruct((bq, d), jnp.float32),
        jax.ShapeDtypeStruct((bc, d), jnp.float32),
        jax.ShapeDtypeStruct((bc,), jnp.float32),
    )


def make_hash_outer(l, m, d):
    """(x (d,), coords (l,m) i32, thr (l,m)) -> (l, m) f32 bits.

    The gather (jnp.take) fuses into the same HLO module as the Pallas
    threshold kernel — one artifact per (L, m) configuration.
    """

    def fn(x, coords, thr):
        gathered = jnp.take(x, coords, axis=0)  # (l, m)
        return (threshold_bits(gathered, thr),)

    return fn, (
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((l, m), jnp.int32),
        jax.ShapeDtypeStruct((l, m), jnp.float32),
    )
