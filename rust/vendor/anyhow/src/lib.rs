//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so DSLSH vendors the
//! subset of `anyhow` it actually uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics follow the real crate where DSLSH depends on them:
//!
//! * `{e}` displays the outermost context (or the root message);
//! * `{e:#}` displays the whole chain, outermost first, `": "`-separated;
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.

use std::error::Error as StdError;
use std::fmt;

/// Dynamic error with a stack of human-readable context frames.
pub struct Error {
    root: Box<dyn StdError + Send + Sync + 'static>,
    /// Context frames, innermost first (index 0 was attached first).
    context: Vec<String>,
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Wrap a displayable message as an error.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { root: Box::new(MessageError(message.to_string())), context: Vec::new() }
    }

    /// Wrap a concrete error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { root: Box::new(error), context: Vec::new() }
    }

    /// Attach a context frame (becomes the new outermost message).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.context.push(ctx.to_string());
        self
    }

    /// The root cause, for downcasting-free inspection.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        &*self.root
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for ctx in self.context.iter().rev() {
                write!(f, "{ctx}: ")?;
            }
            write!(f, "{}", self.root)
        } else {
            match self.context.last() {
                Some(ctx) => write!(f, "{ctx}"),
                None => write!(f, "{}", self.root),
            }
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")?;
        if !self.context.is_empty() {
            write!(f, "\n\nCaused by:\n    {}", self.root)?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_chain_formats_like_anyhow() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading config").unwrap_err().context("starting up");
        assert_eq!(format!("{e}"), "starting up");
        assert_eq!(format!("{e:#}"), "starting up: reading config: missing file");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let v = ok.with_context(|| -> String { unreachable!("must not evaluate") });
        assert_eq!(v.unwrap(), 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }
}
