//! Regenerates **Figure 3** (speedup vs MCC loss, outer LSH grid
//! m_out x L_out on AHE-301-30c, p=8 nu=2). DSLSH_BENCH_SCALE to resize.

use dslsh::experiments::harness::{seed_from_env, Scale};
use dslsh::experiments::tradeoff::{run_fig3, TradeoffOptions};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = TradeoffOptions::paper_defaults(Scale::from_env(), seed_from_env());
    let r = run_fig3(&opts).expect("fig3 failed");
    println!("{}", r.scatter);
    println!("PKNN: {} comps/proc, MCC = {:.3}", r.pknn_comps, r.pknn_mcc);
    println!("{}", r.table.render());
    r.table.save(std::path::Path::new("results"), "fig3").expect("saving results");
    println!("[fig3_tradeoff] done in {:.1}s -> results/fig3.csv", t0.elapsed().as_secs_f64());
}
