//! §Perf: hashing throughput — the table-construction cost driver.
//!
//! Measures composed-hash evaluation (bit-sampling L1 and random-
//! projection cosine) and end-to-end table build rates at the paper's
//! parameters (m_out = 125, L_out = 120). Recorded in EXPERIMENTS.md §Perf.

use dslsh::experiments::report::Table;
use dslsh::lsh::family::{ComposedHash, LayerSpec};
use dslsh::lsh::layer::{LshLayer, SliceView};
use dslsh::util::rng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(3);
    let dim = 30;
    let n = 50_000;
    let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let view = SliceView { data: &data, dim };

    let mut table = Table::new(
        "Hash throughput (m = bits/key)",
        &["family", "m", "keys/s (M)", "bits/s (M)"],
    );
    for (name, spec) in [
        ("bit-sampling L1", LayerSpec::outer_l1(dim, 125, 1, 20.0, 180.0, 1)),
        ("bit-sampling L1", LayerSpec::outer_l1(dim, 200, 1, 20.0, 180.0, 1)),
        ("random-proj cos", LayerSpec::inner_cosine(dim, 65, 1, 2)),
        ("random-proj cos", LayerSpec::inner_cosine(dim, 115, 1, 2)),
    ] {
        let h = spec.instantiate(0);
        // Warmup + measure.
        let mut sink = 0u64;
        let t0 = std::time::Instant::now();
        for i in 0..n {
            sink ^= h.hash(&data[i * dim..(i + 1) * dim]).digest();
        }
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(sink);
        table.row(vec![
            name.to_string(),
            spec.m.to_string(),
            format!("{:.2}", n as f64 / dt / 1e6),
            format!("{:.1}", (n * spec.m) as f64 / dt / 1e6),
        ]);
    }

    // End-to-end single-table build rate at paper parameters.
    let spec = LayerSpec::outer_l1(dim, 125, 120, 20.0, 180.0, 7);
    let t0 = std::time::Instant::now();
    let layer = LshLayer::build(&spec, &view, &[0, 1]);
    let dt = t0.elapsed().as_secs_f64();
    table.row(vec![
        "table build (m=125)".into(),
        "125".into(),
        format!("{:.2}", (2 * n) as f64 / dt / 1e6),
        format!("{:.1}", (2 * n * 125) as f64 / dt / 1e6),
    ]);
    std::hint::black_box(layer.num_entries());

    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "hash_throughput").expect("saving");
    println!("[hash_throughput] -> results/hash_throughput.csv");
}
