//! §Perf ablation: the scan kernel ladder — scalar vs simd4 (vs simd8
//! when compiled with `--features wide-simd`) across dims {30, 32, 37}
//! and candidate batch sizes, plus the AOT JAX/Pallas (XLA/PJRT) engine
//! when its runtime is available. Per-scan wall clock and ns/comparison;
//! the data behind the repo's perf trajectory (`BENCH_engine.json`).
//!
//! `--smoke` (CI, via scripts/tier1.sh) shrinks the corpus, ASSERTS the
//! simd4 kernel is bit-identical to scalar on every (metric, dim) cell,
//! and verifies the CSV artifact is written — correctness plumbing, not
//! timing quality. Full runs additionally refresh `BENCH_engine.json`
//! at the repo root (scalar-vs-SIMD ns/comparison at query batch sizes
//! 1, 16 and 64) when run from the workspace.
//!
//! Not a paper table; recorded in EXPERIMENTS.md §Perf.

use dslsh::engine::native::NativeEngine;
use dslsh::engine::{DistanceEngine, Metric, ScanKernel};
use dslsh::experiments::report::Table;
use dslsh::knn::TopK;
use dslsh::runtime::XlaService;
use dslsh::util::json::{Json, JsonObj};
use dslsh::util::rng::Xoshiro256;
use dslsh::util::stats;

/// Median µs/scan and ns/comparison of `scan` over `ids`.
fn bench_scan(
    engine: &dyn DistanceEngine,
    data: &[f32],
    labels: &[bool],
    q: &[f32],
    dim: usize,
    ids: &[u32],
    reps: usize,
) -> (f64, f64) {
    // Warmup.
    let mut topk = TopK::new(10);
    engine.scan(Metric::L1, q, data, dim, ids, labels, 0, &mut topk);
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let mut topk = TopK::new(10);
        let t0 = std::time::Instant::now();
        engine.scan(Metric::L1, q, data, dim, ids, labels, 0, &mut topk);
        times.push(t0.elapsed().as_secs_f64() * 1e6); // µs
    }
    let med = stats::median(&times);
    (med, med / ids.len() as f64 * 1e3) // (µs/scan, ns/comparison)
}

/// ns/comparison of `scan_batch` with `nq` queries over `ids`.
fn bench_scan_batch(
    engine: &dyn DistanceEngine,
    data: &[f32],
    labels: &[bool],
    qs: &[f32],
    dim: usize,
    nq: usize,
    ids: &[u32],
    reps: usize,
) -> f64 {
    let mut topks: Vec<TopK> = (0..nq).map(|_| TopK::new(10)).collect();
    engine.scan_batch(Metric::L1, qs, data, dim, ids, labels, 0, &mut topks);
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let mut topks: Vec<TopK> = (0..nq).map(|_| TopK::new(10)).collect();
        let t0 = std::time::Instant::now();
        engine.scan_batch(Metric::L1, qs, data, dim, ids, labels, 0, &mut topks);
        times.push(t0.elapsed().as_secs_f64() * 1e9); // ns
    }
    stats::median(&times) / (nq * ids.len()) as f64
}

/// Assert simd4 == scalar bit-identity on scan + scan_batch results —
/// the smoke gate that keeps the ablation honest.
fn assert_kernel_identity(data: &[f32], labels: &[bool], dim: usize, qs: &[f32], ids: &[u32]) {
    let scalar = NativeEngine::with_kernel(ScanKernel::Scalar);
    let simd = NativeEngine::with_kernel(ScanKernel::Simd4);
    let nq = qs.len() / dim;
    for metric in [Metric::L1, Metric::Cosine] {
        let mut a = TopK::new(10);
        let mut b = TopK::new(10);
        scalar.scan(metric, &qs[..dim], data, dim, ids, labels, 0, &mut a);
        simd.scan(metric, &qs[..dim], data, dim, ids, labels, 0, &mut b);
        assert_eq!(
            a.into_sorted(),
            b.into_sorted(),
            "simd4 != scalar on scan (dim={dim}, metric={metric:?})"
        );
        let mut aa: Vec<TopK> = (0..nq).map(|_| TopK::new(10)).collect();
        let mut bb: Vec<TopK> = (0..nq).map(|_| TopK::new(10)).collect();
        scalar.scan_batch(metric, qs, data, dim, ids, labels, 0, &mut aa);
        simd.scan_batch(metric, qs, data, dim, ids, labels, 0, &mut bb);
        for (x, y) in aa.into_iter().zip(bb) {
            assert_eq!(
                x.into_sorted(),
                y.into_sorted(),
                "simd4 != scalar on scan_batch (dim={dim}, metric={metric:?})"
            );
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n: usize = if smoke { 8_192 } else { 200_000 };
    println!("== engine ablation bench ({} mode) ==", if smoke { "smoke" } else { "full" });

    let mut kernels = vec![("scalar", ScanKernel::Scalar), ("simd4", ScanKernel::Simd4)];
    if ScanKernel::simd8_available() {
        kernels.push(("simd8", ScanKernel::Simd8));
    } else {
        println!("simd8 unavailable (needs --features wide-simd + AVX2); skipping its rows");
    }

    // Kernel ladder across dims: the paper's 30-wide windows, the padded
    // 32-wide layout, and a dynamic (non-specialized, tail-carrying) 37.
    let mut table = Table::new(
        "Engine ablation — scan kernel ladder (median)",
        &["kernel", "dim", "batch", "µs/scan", "ns/cmp"],
    );
    let mut rng = Xoshiro256::seed_from_u64(7);
    for &dim in &[30usize, 32, 37] {
        let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.05)).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
        let qs: Vec<f32> = (0..4 * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
        let batches: &[usize] = if smoke { &[1024, 8192] } else { &[1024, 8192, 50_000] };
        for &batch in batches {
            let ids: Vec<u32> = (0..batch).map(|_| rng.gen_below(n as u64) as u32).collect();
            let reps = (200_000 / batch).clamp(5, 400);
            for &(name, kernel) in &kernels {
                let engine = NativeEngine::with_kernel(kernel);
                let (us, ns) = bench_scan(&engine, &data, &labels, &q, dim, &ids, reps);
                table.row(vec![
                    name.to_string(),
                    dim.to_string(),
                    batch.to_string(),
                    format!("{us:.1}"),
                    format!("{ns:.2}"),
                ]);
            }
        }
        // The identity gate runs in every mode; --smoke exists to run it
        // cheaply in CI.
        let gate_ids: Vec<u32> = (0..n as u32).step_by(3).collect();
        assert_kernel_identity(&data, &labels, dim, &qs, &gate_ids);
        println!("identity OK: simd4 == scalar bit-for-bit at dim {dim}");
    }

    // AOT XLA engine for scale context (dim 30 only, its compiled shape).
    if let Ok(svc) = XlaService::start() {
        let dim = 30;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.05)).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
        let ids: Vec<u32> = (0..8192).map(|_| rng.gen_below(n as u64) as u32).collect();
        let xla = svc.engine();
        let (us, ns) = bench_scan(&xla, &data, &labels, &q, dim, &ids, 40);
        table.row(vec![
            "xla".to_string(),
            dim.to_string(),
            "8192".to_string(),
            format!("{us:.1}"),
            format!("{ns:.2}"),
        ]);
    } else {
        println!("XLA runtime unavailable; benchmarking native kernels only");
    }

    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "engine_ablation").expect("saving");
    println!("[engine_ablation] -> results/engine_ablation.csv");

    if smoke {
        let csv = std::fs::read_to_string("results/engine_ablation.csv")
            .expect("smoke: results/engine_ablation.csv must exist");
        for needle in ["scalar", "simd4"] {
            assert!(csv.contains(needle), "smoke: CSV must hold {needle} rows:\n{csv}");
        }
        println!("smoke OK: engine_ablation.csv has {} lines", csv.lines().count());
    }

    // Perf trajectory record: scalar-vs-SIMD ns/comparison at query batch
    // sizes 1/16/64 (dim 30, 8192 candidates). Written to the repo root's
    // BENCH_engine.json when run from the workspace (CI and dev runs);
    // skipped silently elsewhere.
    let bench_root = std::path::Path::new("..");
    if bench_root.join("ROADMAP.md").exists() {
        let dim = 30;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.05)).collect();
        let ids: Vec<u32> = (0..8192.min(n)).map(|_| rng.gen_below(n as u64) as u32).collect();
        let reps = if smoke { 10 } else { 60 };
        let mut obj = JsonObj::new();
        obj.insert("bench", Json::Str("engine_scan".into()));
        obj.insert("metric", Json::Str("ns_per_comparison_l1_dim30".into()));
        obj.insert("candidates", Json::Num(ids.len() as f64));
        obj.insert("mode", Json::Str(if smoke { "smoke" } else { "full" }.into()));
        let mut by_kernel = JsonObj::new();
        for &(name, kernel) in &kernels {
            let engine = NativeEngine::with_kernel(kernel);
            let mut by_batch = JsonObj::new();
            for nq in [1usize, 16, 64] {
                let qs: Vec<f32> =
                    (0..nq * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
                let ns = bench_scan_batch(&engine, &data, &labels, &qs, dim, nq, &ids, reps);
                by_batch.insert(format!("batch_{nq}"), Json::Num((ns * 1000.0).round() / 1000.0));
            }
            by_kernel.insert(name, Json::Obj(by_batch));
        }
        obj.insert("ns_per_comparison", Json::Obj(by_kernel));
        obj.insert("note", Json::Str("recorded by `cargo bench --bench engine_ablation`".into()));
        std::fs::write(bench_root.join("BENCH_engine.json"), Json::Obj(obj).to_string_pretty())
            .expect("writing BENCH_engine.json");
        println!("[engine_ablation] -> BENCH_engine.json (perf trajectory)");
    }
}
