//! §Perf ablation: native Rust scan vs the AOT JAX/Pallas (XLA/PJRT)
//! scan across candidate batch sizes — wall-clock per scan, per-candidate
//! cost, and PJRT call overhead. This is the data behind the batch-ladder
//! choice in python/compile/model.py.
//!
//! Not a paper table; recorded in EXPERIMENTS.md §Perf.

use dslsh::engine::native::NativeEngine;
use dslsh::engine::{DistanceEngine, Metric};
use dslsh::experiments::report::Table;
use dslsh::knn::TopK;
use dslsh::runtime::XlaService;
use dslsh::util::rng::Xoshiro256;
use dslsh::util::stats;

fn bench_engine(
    engine: &dyn DistanceEngine,
    data: &[f32],
    labels: &[bool],
    q: &[f32],
    ids: &[u32],
    reps: usize,
) -> (f64, f64) {
    // Warmup.
    let mut topk = TopK::new(10);
    engine.scan(Metric::L1, q, data, 30, ids, labels, 0, &mut topk);
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let mut topk = TopK::new(10);
        let t0 = std::time::Instant::now();
        engine.scan(Metric::L1, q, data, 30, ids, labels, 0, &mut topk);
        times.push(t0.elapsed().as_secs_f64() * 1e6); // µs
    }
    let med = stats::median(&times);
    (med, med / ids.len() as f64 * 1e3) // (µs/scan, ns/candidate)
}

fn main() {
    let n = 200_000;
    let mut rng = Xoshiro256::seed_from_u64(7);
    let data: Vec<f32> = (0..n * 30).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.05)).collect();
    let q: Vec<f32> = (0..30).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();

    let native = NativeEngine::new();
    let xla_service = match XlaService::start() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("XLA runtime unavailable ({e:#}); benchmarking the native engine only");
            None
        }
    };

    let mut table = Table::new(
        "Engine ablation — candidate scan cost (median)",
        &["batch", "native µs", "native ns/cand", "xla µs", "xla ns/cand", "xla/native"],
    );
    for &batch in &[64usize, 256, 1024, 2048, 8192, 16384, 50000] {
        let ids: Vec<u32> = (0..batch).map(|_| rng.gen_below(n as u64) as u32).collect();
        let reps = (200_000 / batch).clamp(5, 400);
        let (nat_us, nat_ns) = bench_engine(&native, &data, &labels, &q, &ids, reps);
        let (xla_cells, ratio) = match &xla_service {
            Some(svc) => {
                let xla = svc.engine();
                let (xla_us, xla_ns) = bench_engine(&xla, &data, &labels, &q, &ids, reps);
                (
                    (format!("{xla_us:.1}"), format!("{xla_ns:.2}")),
                    format!("{:.1}x", xla_us / nat_us),
                )
            }
            None => (("-".into(), "-".into()), "-".into()),
        };
        table.row(vec![
            batch.to_string(),
            format!("{nat_us:.1}"),
            format!("{nat_ns:.2}"),
            xla_cells.0,
            xla_cells.1,
            ratio,
        ]);
    }
    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "engine_ablation").expect("saving");
    println!("[engine_ablation] -> results/engine_ablation.csv");
}
