//! §Perf: admission-queue latency under offered load — p50/p99 caller
//! latency and the cut-reason mix (fill vs deadline) at arrival rates
//! spanning under- and over-subscription of the cluster.
//!
//! Each submitter thread paces a closed loop to a target inter-arrival
//! interval (submit → wait → spin until the next arrival slot): at long
//! intervals the cluster idles and lone requests ride deadline cuts; at
//! short intervals requests pile up and fill cuts dominate while latency
//! climbs toward the service rate. Saves results/admission_latency.csv.
//!
//! A second section exercises the **priority lanes**: closed-loop
//! monitors under a tight budget share the cluster with open-loop
//! analytics bursts; per-class p50/p99, the per-lane dispatch mix
//! (fill/deadline/aged) and budget overruns go to
//! results/admission_priority.csv.
//!
//! A third section measures **node-side budget enforcement**: the same
//! oversubscribed tight-budget monitor workload under each
//! `BudgetPolicy` (LogOnly = enforcement off, PartialResults, Shed).
//! Enforcement caps the work a blown deadline can burn, so the p99 tail
//! should contract at the price of flagged partial/shed answers —
//! p50/p99 plus overrun/partial/shed counts go to
//! results/admission_enforcement.csv.
//!
//! `--smoke` (CI, via scripts/tier1.sh) shrinks the corpus and load and
//! asserts non-empty CSVs were produced for ALL sections — artifact
//! plumbing (all lanes + all policies) exercised, not timing quality.

// The positional submit/query entry points are deprecated shims over the
// QuerySpec API; this file exercises them on purpose (they must keep
// working bit-identically until removal).
#![allow(deprecated)]

use std::time::{Duration, Instant};

use dslsh::coordinator::{
    build_cluster, AdmissionConfig, AdmissionStats, BudgetPolicy, Class, ClusterConfig,
};
use dslsh::data::{build_corpus, CorpusConfig, WindowSpec};
use dslsh::experiments::report::Table;
use dslsh::lsh::family::LayerSpec;
use dslsh::slsh::SlshParams;
use dslsh::util::stats;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (corpus size, submitter threads, requests per thread per load,
    //  inter-arrival intervals in µs — ∞-ish down to oversubscribed)
    let (n, submitters, per_thread, intervals_us): (usize, usize, usize, Vec<u64>) = if smoke {
        (4_000, 2, 20, vec![500])
    } else {
        (20_000, 8, 150, vec![2_000, 500, 100])
    };
    let max_batch = 16;
    let budget = Duration::from_millis(5);

    println!("== admission latency bench ({} mode) ==", if smoke { "smoke" } else { "full" });
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), n, 200, 42));
    let (lo, hi) = corpus.data.value_range();
    let params =
        SlshParams::lsh_only(LayerSpec::outer_l1(corpus.data.dim, 60, 24, lo, hi, 7), 10);
    let mut cluster =
        build_cluster(&corpus.data, &params, &ClusterConfig::new(2, 2)).expect("cluster");

    let mut table = Table::new(
        format!(
            "Admission latency vs offered load — nu=2 x p=2, max_batch={max_batch}, \
             budget {}ms, {submitters} submitters",
            budget.as_millis()
        ),
        &[
            "interval_us",
            "offered q/s",
            "achieved q/s",
            "p50 ms",
            "p99 ms",
            "cuts fill",
            "cuts deadline",
            "depth hw",
        ],
    );

    for &interval_us in &intervals_us {
        // Fresh queue per load point: counters (including the depth
        // high-water gauge, which never resets) describe THIS load only.
        cluster.orchestrator.enable_admission(
            AdmissionConfig::new(corpus.data.dim, max_batch).with_queue_cap(4096),
        );
        let orch = &cluster.orchestrator;
        let interval = Duration::from_micros(interval_us);
        let t0 = Instant::now();
        let latencies_ms: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..submitters)
                .map(|t| {
                    let corpus = &corpus;
                    s.spawn(move || {
                        let mut lat = Vec::with_capacity(per_thread);
                        for j in 0..per_thread {
                            // Closed loop with pacing: hold the offered
                            // rate while the cluster keeps up; degrade to
                            // saturation beyond it.
                            let due = t0 + interval * j as u32;
                            while Instant::now() < due {
                                std::hint::spin_loop();
                            }
                            let qi = (t * per_thread + j) % corpus.queries.len();
                            let ts = Instant::now();
                            let ticket = orch
                                .submit(corpus.queries.point(qi), budget)
                                .expect("admission rejected");
                            let r = ticket.wait().expect("ticket canceled");
                            lat.push(ts.elapsed().as_secs_f64() * 1e3);
                            std::hint::black_box(r.max_comparisons);
                        }
                        lat
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let elapsed = t0.elapsed().as_secs_f64();
        let snap: AdmissionStats = orch.admission().unwrap().stats();
        let offered = submitters as f64 * 1e6 / interval_us as f64;
        table.row(vec![
            interval_us.to_string(),
            format!("{offered:.0}"),
            format!("{:.0}", latencies_ms.len() as f64 / elapsed),
            format!("{:.2}", stats::percentile(&latencies_ms, 0.50)),
            format!("{:.2}", stats::percentile(&latencies_ms, 0.99)),
            snap.cuts_fill.to_string(),
            snap.cuts_deadline.to_string(),
            snap.high_water.to_string(),
        ]);
    }

    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "admission_latency").expect("saving csv");

    // -- Priority lanes: monitors vs an analytics burst on one cluster --
    //
    // Closed-loop monitors (one query in flight each, tight budget) share
    // the admission queue with open-loop analytics bursts (deep queues,
    // loose budget). With strict-priority lanes + pipelined dispatch the
    // monitor tail must stay near its budget while analytics ride
    // leftover slots, bounded by the aging bound instead of starving.
    let (monitors, analysts, per_monitor, per_analyst) =
        if smoke { (2usize, 1usize, 20usize, 32usize) } else { (4, 2, 150, 256) };
    let budget_monitor = Duration::from_millis(2);
    let budget_analytics = Duration::from_millis(50);
    cluster.orchestrator.enable_admission(
        AdmissionConfig::new(corpus.data.dim, max_batch)
            .with_queue_cap(4096)
            .with_age_bound(Duration::from_millis(20)),
    );
    let orch = &cluster.orchestrator;
    let nq = corpus.queries.len();
    let (monitor_lat, analytics_lat): (Vec<f64>, Vec<f64>) = std::thread::scope(|s| {
        let monitor_handles: Vec<_> = (0..monitors)
            .map(|t| {
                let corpus = &corpus;
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(per_monitor);
                    for j in 0..per_monitor {
                        let qi = (t * per_monitor + j) % nq;
                        let ts = Instant::now();
                        let ticket = orch
                            .submit_class(corpus.queries.point(qi), budget_monitor, Class::Monitor)
                            .expect("monitor admission rejected");
                        let r = ticket.wait().expect("monitor ticket canceled");
                        lat.push(ts.elapsed().as_secs_f64() * 1e3);
                        std::hint::black_box(r.max_comparisons);
                    }
                    lat
                })
            })
            .collect();
        let analytics_handles: Vec<_> = (0..analysts)
            .map(|t| {
                let corpus = &corpus;
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(per_analyst);
                    let mut j = 0;
                    while j < per_analyst {
                        let burst = (per_analyst - j).min(16);
                        let ts = Instant::now();
                        let tickets: Vec<_> = (0..burst)
                            .map(|b| {
                                let qi = (nq / 2 + t * per_analyst + j + b) % nq;
                                orch.submit_class(
                                    corpus.queries.point(qi),
                                    budget_analytics,
                                    Class::Analytics,
                                )
                                .expect("analytics admission rejected")
                            })
                            .collect();
                        for ticket in tickets {
                            ticket.wait().expect("analytics ticket canceled");
                        }
                        lat.push(ts.elapsed().as_secs_f64() * 1e3 / burst as f64);
                        j += burst;
                    }
                    lat
                })
            })
            .collect();
        (
            monitor_handles.into_iter().flat_map(|h| h.join().unwrap()).collect(),
            analytics_handles.into_iter().flat_map(|h| h.join().unwrap()).collect(),
        )
    });
    let snap = orch.admission().unwrap().stats();
    let mut ptable = Table::new(
        format!(
            "Admission priority lanes — nu=2 x p=2, max_batch={max_batch}, \
             monitor budget {}ms x{monitors}, analytics budget {}ms x{analysts}",
            budget_monitor.as_millis(),
            budget_analytics.as_millis()
        ),
        &[
            "class",
            "requests",
            "p50 ms",
            "p99 ms",
            "disp fill",
            "disp deadline",
            "disp aged",
            "overruns",
        ],
    );
    for (name, lat, lane) in [
        ("monitor", &monitor_lat, snap.monitor),
        ("analytics", &analytics_lat, snap.analytics),
    ] {
        ptable.row(vec![
            name.to_string(),
            lane.submitted.to_string(),
            format!("{:.2}", stats::percentile(lat, 0.50)),
            format!("{:.2}", stats::percentile(lat, 0.99)),
            lane.dispatched_fill.to_string(),
            lane.dispatched_deadline.to_string(),
            lane.dispatched_aged.to_string(),
            lane.overruns.to_string(),
        ]);
    }
    println!("{}", ptable.render());
    ptable.save(std::path::Path::new("results"), "admission_priority").expect("saving csv");

    // -- Budget enforcement on vs off: tail latency under oversubscription --
    //
    // The same tight-budget monitor workload, oversubscribed (more
    // concurrent closed-loop submitters than the cluster can serve inside
    // the budget), once per policy. LogOnly is the enforcement-off
    // baseline: a blown deadline still burns a full scan, so the tail
    // stretches with the backlog. PartialResults caps per-cut work at the
    // deadline; Shed refuses already-dead cuts outright — both should
    // contract the p99 at the price of flagged answers (counted in the
    // partial/shed columns; numbers are machine-dependent and not
    // asserted).
    let (enf_threads, per_enf) = if smoke { (4usize, 16usize) } else { (12, 100) };
    let budget_enf = if smoke { Duration::from_micros(500) } else { Duration::from_millis(1) };
    let mut etable = Table::new(
        format!(
            "Admission budget enforcement — nu=2 x p=2, max_batch={max_batch}, \
             monitor budget {}us x{enf_threads} closed-loop",
            budget_enf.as_micros()
        ),
        &["policy", "requests", "p50 ms", "p99 ms", "overruns", "partials", "sheds"],
    );
    for policy in [BudgetPolicy::LogOnly, BudgetPolicy::PartialResults, BudgetPolicy::Shed] {
        cluster.orchestrator.enable_admission(
            AdmissionConfig::new(corpus.data.dim, max_batch)
                .with_queue_cap(4096)
                .with_budget_policy(policy),
        );
        let orch = &cluster.orchestrator;
        let lat: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..enf_threads)
                .map(|t| {
                    let corpus = &corpus;
                    s.spawn(move || {
                        let mut lat = Vec::with_capacity(per_enf);
                        for j in 0..per_enf {
                            let qi = (t * per_enf + j) % corpus.queries.len();
                            let ts = Instant::now();
                            let ticket = orch
                                .submit(corpus.queries.point(qi), budget_enf)
                                .expect("admission rejected");
                            let r = ticket.wait().expect("ticket canceled");
                            lat.push(ts.elapsed().as_secs_f64() * 1e3);
                            std::hint::black_box(r.partial);
                        }
                        lat
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let snap = orch.admission().unwrap().stats();
        etable.row(vec![
            policy.to_string(),
            snap.monitor.submitted.to_string(),
            format!("{:.2}", stats::percentile(&lat, 0.50)),
            format!("{:.2}", stats::percentile(&lat, 0.99)),
            snap.monitor.overruns.to_string(),
            snap.monitor.partials.to_string(),
            snap.monitor.sheds.to_string(),
        ]);
    }
    println!("{}", etable.render());
    etable.save(std::path::Path::new("results"), "admission_enforcement").expect("saving csv");

    // The bench's contract with CI: every section produced a CSV with at
    // least one data row (timing numbers are machine-dependent and NOT
    // asserted).
    for name in ["admission_latency", "admission_priority", "admission_enforcement"] {
        let path = format!("results/{name}.csv");
        let csv = std::fs::read_to_string(&path).unwrap_or_else(|_| panic!("{path} must exist"));
        assert!(
            csv.lines().count() >= 2,
            "{path} must contain a header and at least one data row"
        );
        println!(
            "[admission_latency] -> {path}{}",
            if smoke { " (smoke: CSV verified non-empty)" } else { "" }
        );
    }
}
