//! §Perf: admission-queue latency under offered load — p50/p99 caller
//! latency and the cut-reason mix (fill vs deadline) at arrival rates
//! spanning under- and over-subscription of the cluster.
//!
//! Each submitter thread paces a closed loop to a target inter-arrival
//! interval (submit → wait → spin until the next arrival slot): at long
//! intervals the cluster idles and lone requests ride deadline cuts; at
//! short intervals requests pile up and fill cuts dominate while latency
//! climbs toward the service rate. Saves results/admission_latency.csv.
//!
//! `--smoke` (CI, via scripts/tier1.sh) shrinks the corpus and load and
//! asserts a non-empty CSV was produced — artifact plumbing, not timing
//! quality.

use std::time::{Duration, Instant};

use dslsh::coordinator::{build_cluster, AdmissionConfig, AdmissionStats, ClusterConfig};
use dslsh::data::{build_corpus, CorpusConfig, WindowSpec};
use dslsh::experiments::report::Table;
use dslsh::lsh::family::LayerSpec;
use dslsh::slsh::SlshParams;
use dslsh::util::stats;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (corpus size, submitter threads, requests per thread per load,
    //  inter-arrival intervals in µs — ∞-ish down to oversubscribed)
    let (n, submitters, per_thread, intervals_us): (usize, usize, usize, Vec<u64>) = if smoke {
        (4_000, 2, 20, vec![500])
    } else {
        (20_000, 8, 150, vec![2_000, 500, 100])
    };
    let max_batch = 16;
    let budget = Duration::from_millis(5);

    println!("== admission latency bench ({} mode) ==", if smoke { "smoke" } else { "full" });
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), n, 200, 42));
    let (lo, hi) = corpus.data.value_range();
    let params =
        SlshParams::lsh_only(LayerSpec::outer_l1(corpus.data.dim, 60, 24, lo, hi, 7), 10);
    let mut cluster =
        build_cluster(&corpus.data, &params, &ClusterConfig::new(2, 2)).expect("cluster");

    let mut table = Table::new(
        format!(
            "Admission latency vs offered load — nu=2 x p=2, max_batch={max_batch}, \
             budget {}ms, {submitters} submitters",
            budget.as_millis()
        ),
        &[
            "interval_us",
            "offered q/s",
            "achieved q/s",
            "p50 ms",
            "p99 ms",
            "cuts fill",
            "cuts deadline",
            "depth hw",
        ],
    );

    for &interval_us in &intervals_us {
        // Fresh queue per load point: counters (including the depth
        // high-water gauge, which never resets) describe THIS load only.
        cluster.orchestrator.enable_admission(
            AdmissionConfig::new(corpus.data.dim, max_batch).with_queue_cap(4096),
        );
        let orch = &cluster.orchestrator;
        let interval = Duration::from_micros(interval_us);
        let t0 = Instant::now();
        let latencies_ms: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..submitters)
                .map(|t| {
                    let corpus = &corpus;
                    s.spawn(move || {
                        let mut lat = Vec::with_capacity(per_thread);
                        for j in 0..per_thread {
                            // Closed loop with pacing: hold the offered
                            // rate while the cluster keeps up; degrade to
                            // saturation beyond it.
                            let due = t0 + interval * j as u32;
                            while Instant::now() < due {
                                std::hint::spin_loop();
                            }
                            let qi = (t * per_thread + j) % corpus.queries.len();
                            let ts = Instant::now();
                            let ticket = orch
                                .submit(corpus.queries.point(qi), budget)
                                .expect("admission rejected");
                            let r = ticket.wait().expect("ticket canceled");
                            lat.push(ts.elapsed().as_secs_f64() * 1e3);
                            std::hint::black_box(r.max_comparisons);
                        }
                        lat
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let elapsed = t0.elapsed().as_secs_f64();
        let snap: AdmissionStats = orch.admission().unwrap().stats();
        let offered = submitters as f64 * 1e6 / interval_us as f64;
        table.row(vec![
            interval_us.to_string(),
            format!("{offered:.0}"),
            format!("{:.0}", latencies_ms.len() as f64 / elapsed),
            format!("{:.2}", stats::percentile(&latencies_ms, 0.50)),
            format!("{:.2}", stats::percentile(&latencies_ms, 0.99)),
            snap.cuts_fill.to_string(),
            snap.cuts_deadline.to_string(),
            snap.high_water.to_string(),
        ]);
    }

    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "admission_latency").expect("saving csv");

    // The bench's contract with CI: it produced a CSV with at least one
    // data row (timing numbers are machine-dependent and NOT asserted).
    let csv = std::fs::read_to_string("results/admission_latency.csv")
        .expect("results/admission_latency.csv must exist");
    assert!(
        csv.lines().count() >= 2,
        "admission_latency.csv must contain a header and at least one data row"
    );
    println!(
        "[admission_latency] -> results/admission_latency.csv{}",
        if smoke { " (smoke: CSV verified non-empty)" } else { "" }
    );
}
