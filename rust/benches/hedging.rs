//! §Perf: hedged requests vs a straggling replica.
//!
//! One shard replica is an induced straggler: every Nth request it
//! sleeps `stall` before answering — the paper's late-answer-is-useless
//! failure mode, in miniature. Three closed-loop scenarios over the same
//! replicated cluster (ν=2 × r=2), one CSV (`results/hedging.csv`):
//!
//! * **clean** — no straggler, hedging off: the baseline tail.
//! * **straggler unhedged** — hedging off: every stall lands in the
//!   caller's latency, so p99/p999 inflate to ~`stall`.
//! * **straggler hedged** — hedge after a small delay: the dispatcher
//!   re-issues the late request to the twin and the first reply wins, so
//!   the tail collapses back toward the hedge delay. `hedges` /
//!   `hedge_wins` from [`Orchestrator::failover_stats`] ride along as
//!   evidence it was the hedge, not luck.
//!
//! `--smoke` (CI, via scripts/tier1.sh) shrinks the corpus and load and
//! asserts the CSV holds every scenario row and that the hedged run
//! actually hedged — artifact plumbing, not timing quality.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dslsh::coordinator::{FailoverConfig, NodeError, NodeHandle, Orchestrator, ReplicaSet};
use dslsh::data::{build_corpus, CorpusConfig, Dataset, WindowSpec};
use dslsh::engine::native::NativeEngine;
use dslsh::engine::DistanceEngine;
use dslsh::experiments::report::Table;
use dslsh::knn::predict::VoteConfig;
use dslsh::lsh::family::LayerSpec;
use dslsh::node::node::{LocalNode, NodeInfo, NodeReply};
use dslsh::slsh::SlshParams;
use dslsh::util::stats;
use dslsh::util::threadpool::chunk_ranges;

/// A replica that answers correctly but sleeps `stall` on every
/// `every`-th request it receives — a real straggler (late, not wrong,
/// not dead), so health stays `Up`/`Suspect` and only the hedge path can
/// save the tail.
struct StraggleNode {
    inner: LocalNode,
    every: usize,
    stall: Duration,
    seen: usize,
}

impl StraggleNode {
    fn pause(&mut self) {
        self.seen += 1;
        if self.seen % self.every == 0 {
            std::thread::sleep(self.stall);
        }
    }
}

impl NodeHandle for StraggleNode {
    fn node_id(&self) -> usize {
        LocalNode::node_id(&self.inner)
    }

    fn info(&self) -> NodeInfo {
        self.inner.info().clone()
    }

    fn query(&mut self, q: &[f32]) -> Result<NodeReply, NodeError> {
        self.pause();
        Ok(self.inner.query(q))
    }

    fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Result<Vec<NodeReply>, NodeError> {
        self.pause();
        Ok(self.inner.query_batch(qs, nq))
    }
}

fn engines(p: usize) -> Vec<Box<dyn DistanceEngine>> {
    (0..p).map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>).collect()
}

/// ν=2 shards × 2 replicas; replica 0 of shard 0 becomes the straggler
/// when `straggle` is set. Heartbeats and request timeouts are parked
/// far out so the hedge delay is the only timer in play.
fn replicated(
    data: &Dataset,
    params: &SlshParams,
    hedge_after: Duration,
    straggle: Option<(usize, Duration)>,
) -> Orchestrator {
    let p = 2usize;
    let mut sets = Vec::new();
    for (shard, range) in chunk_ranges(data.len(), 2).into_iter().enumerate() {
        let base = range.start as u64;
        let slice = Arc::new(data.shard(range));
        let mut replicas: Vec<Box<dyn NodeHandle>> = Vec::new();
        for rep in 0..2 {
            let node = LocalNode::spawn(shard, Arc::clone(&slice), base, params, p, engines(p));
            match straggle {
                Some((every, stall)) if rep == 0 && shard == 0 => {
                    let s = StraggleNode { inner: node, every, stall, seen: 0 };
                    replicas.push(Box::new(s));
                }
                _ => replicas.push(Box::new(node)),
            }
        }
        sets.push(ReplicaSet::new(shard, replicas));
    }
    let failover = FailoverConfig {
        hedge_after,
        request_timeout: Duration::from_secs(30),
        heartbeat_every: Duration::from_secs(3600),
        ..FailoverConfig::default()
    };
    Orchestrator::start_replicated(sets, params.k, VoteConfig::default(), failover)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (corpus points, timed queries, straggle period, stall, hedge delay)
    let (n, n_queries, every, stall, hedge) = if smoke {
        (4_000, 40, 5, Duration::from_millis(5), Duration::from_millis(1))
    } else {
        (20_000, 400, 10, Duration::from_millis(20), Duration::from_millis(2))
    };
    let off = Duration::from_secs(30); // "hedging off": longer than any stall

    println!("== hedging bench ({} mode) ==", if smoke { "smoke" } else { "full" });
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), n, 200, 42));
    let (lo, hi) = corpus.data.value_range();
    let params =
        SlshParams::lsh_only(LayerSpec::outer_l1(corpus.data.dim, 40, 12, lo, hi, 7), 10);

    let mut table = Table::new(
        format!(
            "Hedged fan-out vs one straggler — nu=2 x r=2, stall {} ms every {} requests",
            stall.as_millis(),
            every
        ),
        &["scenario", "hedge ms", "p50 ms", "p99 ms", "p999 ms", "hedges", "hedge wins"],
    );

    let scenarios: [(&str, Duration, Option<(usize, Duration)>); 3] = [
        ("clean", off, None),
        ("straggler unhedged", off, Some((every, stall))),
        ("straggler hedged", hedge, Some((every, stall))),
    ];
    for (name, hedge_after, straggle) in scenarios {
        let orch = replicated(&corpus.data, &params, hedge_after, straggle);
        let mut lat = Vec::with_capacity(n_queries);
        for i in 0..n_queries {
            let q = corpus.queries.point(i % corpus.queries.len());
            let t = Instant::now();
            std::hint::black_box(orch.query(q).expect("query"));
            lat.push(t.elapsed().as_secs_f64() * 1e3);
        }
        let fo = orch.failover_stats();
        println!(
            "{name:>18}: p50 {:.2} ms  p99 {:.2} ms  p999 {:.2} ms  ({} hedges, {} wins)",
            stats::percentile(&lat, 0.50),
            stats::percentile(&lat, 0.99),
            stats::percentile(&lat, 0.999),
            fo.hedges,
            fo.hedge_wins,
        );
        let hedge_label = if hedge_after == off {
            "off".to_string()
        } else {
            hedge_after.as_millis().to_string()
        };
        table.row(vec![
            name.into(),
            hedge_label,
            format!("{:.3}", stats::percentile(&lat, 0.50)),
            format!("{:.3}", stats::percentile(&lat, 0.99)),
            format!("{:.3}", stats::percentile(&lat, 0.999)),
            fo.hedges.to_string(),
            fo.hedge_wins.to_string(),
        ]);
        if smoke && name == "straggler hedged" {
            assert!(fo.hedges >= 1, "hedged scenario never hedged a stalled request");
        }
    }

    println!();
    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "hedging").expect("saving csv");
    println!("saved results/hedging.csv");

    if smoke {
        let csv = std::fs::read_to_string("results/hedging.csv")
            .expect("results/hedging.csv must exist");
        assert!(
            csv.lines().count() >= 1 + scenarios.len(),
            "smoke: hedging.csv must hold every scenario row:\n{csv}"
        );
        println!("smoke OK: hedging.csv has {} lines", csv.lines().count());
    }
}
