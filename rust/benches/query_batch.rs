//! §Perf: batched candidate-scan throughput — single-query vs the
//! register-blocked multi-query tile kernel, plus end-to-end batched SLSH
//! resolution (batched hashing + scratch arena reuse).
//!
//! A fixed stream of queries is resolved at admission batch sizes
//! 1/4/16/64; every configuration performs the SAME comparisons, so the
//! queries/s and ns/comparison columns isolate the memory-traffic
//! amortization (each data row fetched once per query tile instead of
//! once per query). Recorded in CHANGES.md / EXPERIMENTS.md §Perf.

use dslsh::engine::native::NativeEngine;
use dslsh::engine::{DistanceEngine, Metric};
use dslsh::experiments::report::Table;
use dslsh::knn::TopK;
use dslsh::lsh::family::LayerSpec;
use dslsh::slsh::{BatchOutput, QueryScratch, SlshIndex, SlshParams};
use dslsh::util::rng::Xoshiro256;
use dslsh::util::stats;

const DIM: usize = 30;
const QUERIES: usize = 64;
const REPS: usize = 7;

/// Median-of-reps wall-clock (seconds) for resolving the whole query
/// stream at one admission batch size through the engine scan.
fn bench_scan(
    engine: &NativeEngine,
    qs: &[f32],
    data: &[f32],
    labels: &[bool],
    ids: &[u32],
    batch: usize,
) -> f64 {
    let mut topks: Vec<TopK> = (0..batch).map(|_| TopK::new(10)).collect();
    let mut times = Vec::with_capacity(REPS);
    for rep in 0..=REPS {
        let t0 = std::time::Instant::now();
        let mut start = 0usize;
        while start < QUERIES {
            let end = (start + batch).min(QUERIES);
            let nq = end - start;
            for t in topks[..nq].iter_mut() {
                t.reset(10);
            }
            let c = engine.scan_batch(
                Metric::L1,
                &qs[start * DIM..end * DIM],
                data,
                DIM,
                ids,
                labels,
                0,
                &mut topks[..nq],
            );
            std::hint::black_box(c);
            start = end;
        }
        if rep > 0 {
            times.push(t0.elapsed().as_secs_f64()); // rep 0 = warmup
        }
    }
    stats::median(&times)
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(11);
    // Shard large enough that candidate rows do not live in cache.
    let n = 200_000;
    let data: Vec<f32> = (0..n * DIM).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.05)).collect();
    let qs: Vec<f32> = (0..QUERIES * DIM).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    // A scattered candidate list shaped like an LSH union (20k of 200k).
    let mut ids: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut ids);
    ids.truncate(20_000);
    ids.sort_unstable();

    let engine = NativeEngine::new();
    let mut table = Table::new(
        "Batched candidate scan — single-thread, 64 queries x 20k candidates, d=30",
        &["batch", "queries/s", "ns/comparison", "speedup vs b=1"],
    );
    let mut base_qps = 0.0f64;
    for &batch in &[1usize, 4, 16, 64] {
        let secs = bench_scan(&engine, &qs, &data, &labels, &ids, batch);
        let qps = QUERIES as f64 / secs;
        let ns_per_cmp = secs * 1e9 / (QUERIES * ids.len()) as f64;
        if batch == 1 {
            base_qps = qps;
        }
        table.row(vec![
            batch.to_string(),
            format!("{qps:.1}"),
            format!("{ns_per_cmp:.2}"),
            format!("{:.2}x", qps / base_qps),
        ]);
    }
    println!("{}", table.render());

    // End-to-end SLSH resolution: batched hashing + candidate gathering +
    // scan through the reused scratch arena, vs the per-query path.
    let n_idx = 50_000;
    let idx_data: Vec<f32> =
        (0..n_idx * DIM).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let idx_labels: Vec<bool> = (0..n_idx).map(|_| rng.gen_bool(0.05)).collect();
    let params =
        SlshParams::lsh_only(LayerSpec::outer_l1(DIM, 60, 24, 20.0, 180.0, 7), 10);
    let view = dslsh::lsh::layer::SliceView { data: &idx_data, dim: DIM };
    let idx = SlshIndex::build_full(&params, &view);
    let mut scratch = QueryScratch::new(n_idx);
    let mut out = BatchOutput::new();

    let mut table2 = Table::new(
        "Batched SLSH resolution — 64 queries, m=60 L=24 over 50k points",
        &["batch", "queries/s", "speedup vs b=1"],
    );
    let mut base2 = 0.0f64;
    for &batch in &[1usize, 4, 16, 64] {
        let mut times = Vec::with_capacity(REPS);
        for rep in 0..=REPS {
            let t0 = std::time::Instant::now();
            let mut start = 0usize;
            while start < QUERIES {
                let end = (start + batch).min(QUERIES);
                idx.query_batch(
                    &engine,
                    &qs[start * DIM..end * DIM],
                    &idx_data,
                    &idx_labels,
                    0,
                    &mut scratch,
                    &mut out,
                );
                std::hint::black_box(out.len());
                start = end;
            }
            if rep > 0 {
                times.push(t0.elapsed().as_secs_f64());
            }
        }
        let qps = QUERIES as f64 / stats::median(&times);
        if batch == 1 {
            base2 = qps;
        }
        table2.row(vec![
            batch.to_string(),
            format!("{qps:.1}"),
            format!("{:.2}x", qps / base2),
        ]);
    }
    println!("{}", table2.render());

    table.save(std::path::Path::new("results"), "query_batch_scan").expect("saving");
    table2.save(std::path::Path::new("results"), "query_batch_slsh").expect("saving");
    println!("[query_batch] -> results/query_batch_scan.csv, results/query_batch_slsh.csv");
}
