//! Regenerates **Table 1** (employed ABP datasets): dataset sizes and
//! class imbalance from the rolling-window pipeline vs the paper's values.
//! Scale via DSLSH_BENCH_SCALE=smoke|default|full.

use dslsh::experiments::harness::{seed_from_env, Scale};
use dslsh::experiments::table1::{run, Table1Options};

fn main() {
    let t0 = std::time::Instant::now();
    let table = run(&Table1Options { scale: Scale::from_env(), seed: seed_from_env() })
        .expect("table1 failed");
    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "table1").expect("saving results");
    println!("[table1_datasets] done in {:.1}s -> results/table1.csv", t0.elapsed().as_secs_f64());
}
