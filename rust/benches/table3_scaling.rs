//! Regenerates **Table 3** (strong scaling on AHE-51-5c, p=8,
//! pv in {8..40}, ~10% tolerated MCC loss). DSLSH_BENCH_SCALE to resize.

use dslsh::experiments::harness::{seed_from_env, Scale};
use dslsh::experiments::scaling::{run, ScalingOptions, ScalingTable};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = ScalingOptions::for_table(ScalingTable::Table3, Scale::from_env(), seed_from_env());
    let r = run(ScalingTable::Table3, &opts).expect("table3 failed");
    println!("PKNN MCC = {:.3}", r.pknn_mcc);
    println!("{}", r.table.render());
    r.table.save(std::path::Path::new("results"), "table3").expect("saving results");
    println!("[table3_scaling] done in {:.1}s -> results/table3.csv", t0.elapsed().as_secs_f64());
}
