//! §Perf: streaming-ingest throughput and its cost to query latency.
//!
//! Two sections, one CSV (`results/ingest.csv`):
//!
//! * **insert throughput** — a standalone `LiveIndex` absorbing the
//!   corpus at several insert-batch sizes: points/s hashed into the
//!   delta plus the seals performed along the way (a seal is a full
//!   segment build — the amortized cost of keeping SLSH semantics).
//! * **query latency vs ingest rate** — a live cluster (ν=2 × p=2)
//!   serving a closed-loop monitor while an ingest thread streams
//!   windows at a paced target rate: query p50/p99 as the ingest rate
//!   climbs from zero (quiet ward) past seal-storm territory. The
//!   "rate 0" row is the baseline the other rows are read against.
//!
//! `--smoke` (CI, via scripts/tier1.sh) shrinks the corpus and load and
//! asserts a non-empty CSV was produced — artifact plumbing, not timing
//! quality.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dslsh::coordinator::{build_live_cluster, ClusterConfig};
use dslsh::data::{build_corpus, CorpusConfig, WindowSpec};
use dslsh::engine::native::NativeEngine;
use dslsh::experiments::report::Table;
use dslsh::lsh::family::LayerSpec;
use dslsh::slsh::{BatchOutput, LiveIndex, LiveScratch, SealPolicy, SlshParams};
use dslsh::util::clock::SystemClock;
use dslsh::util::stats;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (corpus points, seal size, insert-batch sizes, paced ingest rates
    //  in points/s — 0 = no ingest baseline, queries per rate point)
    let (n, seal, batches, rates, n_queries): (usize, usize, Vec<usize>, Vec<u64>, usize) =
        if smoke {
            (4_000, 1_000, vec![64], vec![0, 20_000], 30)
        } else {
            (30_000, 4_000, vec![1, 16, 64, 256], vec![0, 2_000, 20_000, 100_000], 300)
        };

    println!("== ingest bench ({} mode) ==", if smoke { "smoke" } else { "full" });
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), n, 200, 42));
    let (lo, hi) = corpus.data.value_range();
    let params =
        SlshParams::lsh_only(LayerSpec::outer_l1(corpus.data.dim, 60, 24, lo, hi, 7), 10);

    let mut table = Table::new(
        format!("Streaming ingest — n={n}, seal at {seal} points, nu=2 x p=2 for the rate sweep"),
        &[
            "scenario",
            "insert batch",
            "target pts/s",
            "inserts/s",
            "sealed",
            "query p50 ms",
            "query p99 ms",
        ],
    );

    // -- Section 1: standalone insert throughput ---------------------------
    let d = &corpus.data;
    for &batch in &batches {
        let live = LiveIndex::new(
            &params,
            SealPolicy::by_size(seal),
            Arc::new(SystemClock::new()),
        );
        let t0 = Instant::now();
        let mut at = 0usize;
        while at < d.len() {
            let take = batch.min(d.len() - at);
            live.insert_batch(&d.points[at * d.dim..(at + take) * d.dim], &d.labels[at..at + take]);
            at += take;
        }
        let dt = t0.elapsed().as_secs_f64();
        let rate = d.len() as f64 / dt;
        println!(
            "standalone insert: batch {batch:>4} → {rate:>10.0} pts/s, {} seals",
            live.sealed_segments()
        );
        table.row(vec![
            "standalone".into(),
            batch.to_string(),
            "-".into(),
            format!("{rate:.0}"),
            live.sealed_segments().to_string(),
            "-".into(),
            "-".into(),
        ]);
        // Sanity: everything searchable afterwards.
        let engine = NativeEngine::new();
        let (mut scratch, mut out) = (LiveScratch::new(), BatchOutput::new());
        live.query_batch(&engine, d.point(d.len() / 2), &mut scratch, &mut out);
        assert!(out.neighbors(0).iter().any(|nb| nb.dist == 0.0), "ingested point lost");
    }

    // -- Section 2: query latency under paced ingest -----------------------
    let ingest_batch = 64usize;
    for &rate in &rates {
        let cluster = build_live_cluster(
            &params,
            &ClusterConfig::new(2, 2),
            SealPolicy::by_size(seal),
        )
        .expect("live cluster");
        // Pre-load half the corpus so queries always have something to
        // find; the paced stream then ingests the other half.
        let preload = d.len() / 2;
        let mut at = 0usize;
        while at < preload {
            let take = 512.min(preload - at);
            cluster
                .insert_batch(&d.points[at * d.dim..(at + take) * d.dim], &d.labels[at..at + take])
                .expect("preload insert");
            at += take;
        }
        let done = std::sync::atomic::AtomicBool::new(false);
        let (lat_ms, achieved): (Vec<f64>, f64) = std::thread::scope(|s| {
            let ingester = s.spawn(|| {
                if rate == 0 {
                    return 0.0;
                }
                let t0 = Instant::now();
                let mut sent = 0usize;
                let mut at = preload;
                while !done.load(std::sync::atomic::Ordering::Relaxed) {
                    let due = t0 + Duration::from_secs_f64(sent as f64 / rate as f64);
                    while Instant::now() < due {
                        std::hint::spin_loop();
                    }
                    let take = ingest_batch.min(d.len() - at);
                    cluster
                        .insert_batch(
                            &d.points[at * d.dim..(at + take) * d.dim],
                            &d.labels[at..at + take],
                        )
                        .expect("paced insert");
                    sent += take;
                    at += take;
                    if at >= d.len() {
                        at = preload; // wrap: re-offer the tail (ids keep advancing)
                    }
                }
                sent as f64 / t0.elapsed().as_secs_f64()
            });
            let lat: Vec<f64> = (0..n_queries)
                .map(|i| {
                    let q = corpus.queries.point(i % corpus.queries.len());
                    let ts = Instant::now();
                    let r = cluster.query(q).expect("paced query");
                    std::hint::black_box(r.max_comparisons);
                    ts.elapsed().as_secs_f64() * 1e3
                })
                .collect();
            done.store(true, std::sync::atomic::Ordering::Relaxed);
            (lat, ingester.join().unwrap())
        });
        let ing = cluster.ingest_stats();
        println!(
            "rate {rate:>7} pts/s → achieved {achieved:>9.0}, {} seals, query p50 {:.2} ms p99 {:.2} ms",
            ing.sealed_segments,
            stats::percentile(&lat_ms, 0.50),
            stats::percentile(&lat_ms, 0.99),
        );
        table.row(vec![
            "cluster".into(),
            ingest_batch.to_string(),
            rate.to_string(),
            format!("{achieved:.0}"),
            ing.sealed_segments.to_string(),
            format!("{:.3}", stats::percentile(&lat_ms, 0.50)),
            format!("{:.3}", stats::percentile(&lat_ms, 0.99)),
        ]);
    }

    println!();
    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "ingest").expect("saving csv");
    println!("saved results/ingest.csv");

    if smoke {
        let csv = std::fs::read_to_string("results/ingest.csv")
            .expect("results/ingest.csv must exist");
        assert!(
            csv.lines().count() >= 1 + batches.len() + rates.len(),
            "smoke: ingest.csv must hold every scenario row:\n{csv}"
        );
        println!("smoke OK: ingest.csv has {} lines", csv.lines().count());
    }
}
