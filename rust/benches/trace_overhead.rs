//! §Perf: observability overhead on the serving hot path — what the
//! always-on tier (per-lane / per-shard histograms, one clock read per
//! stage boundary) and the opt-in span-collection tier each cost.
//!
//! Three measurements:
//! 1. Primitive costs, ns/op: a single `Histogram::record`, a full
//!    `Tracer::record_lane` (three records), and the whole traced-query
//!    span lifecycle (`mint` → `span` → `finish`) with collection ON —
//!    the mutex tier a debug session pays.
//! 2. End-to-end µs/query on a real cluster with span collection OFF vs
//!    ON, and the overhead percentage between them.
//! 3. The parity gate, every mode: results with collection ON must be
//!    bit-identical to collection OFF — tracing observes, never steers.
//!
//! `--smoke` (CI, via scripts/tier1.sh) shrinks the corpus and reps and
//! asserts the CSV artifact was written — plumbing, not timing quality.
//! Runs from the workspace additionally refresh `BENCH_observability.json`
//! at the repo root; elsewhere that step is skipped silently.
//!
//! Not a paper table; recorded in EXPERIMENTS.md §Perf.

use std::sync::Arc;
use std::time::Instant;

use dslsh::coordinator::{build_cluster, ClusterConfig, QueryResult, SystemClock};
use dslsh::data::{build_corpus, CorpusConfig, WindowSpec};
use dslsh::experiments::report::Table;
use dslsh::lsh::family::LayerSpec;
use dslsh::runtime::hist::Histogram;
use dslsh::runtime::trace::Tracer;
use dslsh::slsh::SlshParams;
use dslsh::util::json::{Json, JsonObj};
use dslsh::util::stats;

fn ns_per_op(n: usize, mut f: impl FnMut()) -> f64 {
    // Warmup.
    for _ in 0..n.min(1000) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e9 / n as f64
}

/// Everything workload-determined in a result (latency excluded).
fn assert_same(a: &QueryResult, b: &QueryResult, ctx: &str) {
    assert_eq!(a.neighbors, b.neighbors, "{ctx}: neighbors");
    assert!(a.positive_share == b.positive_share, "{ctx}: positive_share");
    assert_eq!(a.prediction, b.prediction, "{ctx}: prediction");
    assert_eq!(a.max_comparisons, b.max_comparisons, "{ctx}: max_comparisons");
    assert_eq!(a.per_node_comparisons, b.per_node_comparisons, "{ctx}: per_node_comparisons");
    assert_eq!(a.partial, b.partial, "{ctx}: partial");
    assert_eq!(a.shed_nodes, b.shed_nodes, "{ctx}: shed_nodes");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n, nq, reps, prim_ops): (usize, usize, usize, usize) =
        if smoke { (4_000, 8, 3, 50_000) } else { (40_000, 20, 30, 2_000_000) };
    println!("== trace overhead bench ({} mode) ==", if smoke { "smoke" } else { "full" });

    // --- 1. Primitive costs ---
    let hist = Histogram::new();
    let mut v = 0u64;
    let hist_record_ns = ns_per_op(prim_ops, || {
        v = v.wrapping_add(17) & 0xFFFF;
        hist.record(v);
    });
    let tracer = Tracer::new(Arc::new(SystemClock::new()), 2);
    let record_lane_ns = ns_per_op(prim_ops, || {
        tracer.record_lane(0, 3, 40, 43);
    });
    tracer.set_collect(true);
    let span_ops = prim_ops / 10;
    let mint_span_finish_ns = ns_per_op(span_ops.max(1), || {
        let id = tracer.mint(0);
        tracer.span(id, "service", 0, 1_000);
        tracer.finish(id, 0, 5, false, false);
    });
    let mut table = Table::new(
        "Observability overhead — primitives and end-to-end",
        &["measurement", "value", "unit"],
    );
    table.row(vec!["hist_record".into(), format!("{hist_record_ns:.1}"), "ns/op".into()]);
    table.row(vec!["record_lane".into(), format!("{record_lane_ns:.1}"), "ns/op".into()]);
    table.row(vec![
        "mint_span_finish".into(),
        format!("{mint_span_finish_ns:.1}"),
        "ns/op (collection ON)".into(),
    ]);

    // --- 2 + 3. End-to-end with the parity gate ---
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), n, nq, 42));
    let (lo, hi) = corpus.data.value_range();
    let params =
        SlshParams::lsh_only(LayerSpec::outer_l1(corpus.data.dim, 60, 24, lo, hi, 7), 10);
    let cluster =
        build_cluster(&corpus.data, &params, &ClusterConfig::new(2, 2)).expect("cluster");

    let run = |label: &str| -> (f64, Vec<QueryResult>) {
        let mut lat_us = Vec::with_capacity(reps * nq);
        let mut last = Vec::new();
        for rep in 0..reps {
            let mut results = Vec::with_capacity(nq);
            for i in 0..nq {
                let t0 = Instant::now();
                let r = cluster.query(corpus.queries.point(i)).expect(label);
                lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
                results.push(r);
            }
            if rep == 0 {
                last = results;
            }
        }
        (stats::median(&lat_us), last)
    };

    // Collection OFF: the always-on tier only. Park the slow threshold at
    // the ceiling so the ring mutex is never touched by wall-clock noise.
    let cluster_tracer = cluster.tracer();
    cluster_tracer.set_slow_threshold_us(u64::MAX);
    let (off_us, off_results) = run("collect off");
    // Collection ON: spans assembled for every query.
    cluster_tracer.set_collect(true);
    let (on_us, on_results) = run("collect on");
    cluster_tracer.set_collect(false);

    for (i, (a, b)) in off_results.iter().zip(&on_results).enumerate() {
        assert_same(a, b, &format!("query {i} traced vs untraced"));
    }
    println!("parity OK: collection ON is bit-identical to OFF over {nq} queries");

    let overhead_pct = (on_us - off_us) / off_us * 100.0;
    table.row(vec!["query_collect_off".into(), format!("{off_us:.1}"), "µs/query (median)".into()]);
    table.row(vec!["query_collect_on".into(), format!("{on_us:.1}"), "µs/query (median)".into()]);
    table.row(vec!["span_overhead".into(), format!("{overhead_pct:.1}"), "%".into()]);

    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "trace_overhead").expect("saving");
    println!("[trace_overhead] -> results/trace_overhead.csv");

    if smoke {
        let csv = std::fs::read_to_string("results/trace_overhead.csv")
            .expect("smoke: results/trace_overhead.csv must exist");
        for needle in ["hist_record", "query_collect_on"] {
            assert!(csv.contains(needle), "smoke: CSV must hold {needle} rows:\n{csv}");
        }
        println!("smoke OK: trace_overhead.csv has {} lines", csv.lines().count());
    }

    // Perf trajectory record, written at the repo root when run from the
    // workspace (CI and dev runs); skipped silently elsewhere.
    let bench_root = std::path::Path::new("..");
    if bench_root.join("ROADMAP.md").exists() {
        let round = |x: f64| (x * 1000.0).round() / 1000.0;
        let mut obj = JsonObj::new();
        obj.insert("bench", Json::Str("trace_overhead".into()));
        obj.insert("metric", Json::Str("observability_cost".into()));
        obj.insert("mode", Json::Str(if smoke { "smoke" } else { "full" }.into()));
        let mut prim = JsonObj::new();
        prim.insert("hist_record", Json::Num(round(hist_record_ns)));
        prim.insert("record_lane", Json::Num(round(record_lane_ns)));
        prim.insert("mint_span_finish", Json::Num(round(mint_span_finish_ns)));
        obj.insert("primitives_ns", Json::Obj(prim));
        let mut q = JsonObj::new();
        q.insert("collect_off", Json::Num(round(off_us)));
        q.insert("collect_on", Json::Num(round(on_us)));
        obj.insert("query_us_median", Json::Obj(q));
        obj.insert("span_overhead_pct", Json::Num(round(overhead_pct)));
        obj.insert("note", Json::Str("recorded by `cargo bench --bench trace_overhead`".into()));
        std::fs::write(
            bench_root.join("BENCH_observability.json"),
            Json::Obj(obj).to_string_pretty(),
        )
        .expect("writing BENCH_observability.json");
        println!("[trace_overhead] -> BENCH_observability.json (perf trajectory)");
    }
}
