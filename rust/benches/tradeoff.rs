//! §Perf: the multi-probe accuracy/work frontier — the curve a caller
//! rides when turning [`QuerySpec::with_probes`].
//!
//! One cluster (ν=2 × p=2) over an AHE-51-5c corpus; the same query set
//! swept at `probes ∈ {1, 2, 4, 8}`. Per operating point, one CSV row
//! (`results/tradeoff.csv`):
//!
//! * **comparisons** — median per-query max (the paper's speed metric)
//!   and the run's summed total: the price of each extra probe.
//! * **recall@K** — overlap with the exhaustive L1 K-NN over the full
//!   corpus: what the extra buckets buy. Probe sequences are prefixes,
//!   so candidates (and, up to distance ties, recall) only grow with P.
//! * **MCC** — downstream prediction quality against the true labels.
//! * **p50 latency** — the wall-clock cost of the wider scan.
//!
//! `--smoke` (CI, via scripts/tier1.sh) shrinks the corpus and asserts
//! the artifact contract: the CSV holds every probe row and total
//! comparisons are STRICTLY increasing in P — the knob must actually
//! buy work at every step, not merely not break.
//!
//! ```bash
//! cargo bench --bench tradeoff            # full sweep
//! cargo bench --bench tradeoff -- --smoke # CI artifact check
//! ```

use std::time::Instant;

use dslsh::coordinator::{build_cluster, ClusterConfig, QuerySpec};
use dslsh::data::{build_corpus, CorpusConfig, WindowSpec};
use dslsh::engine::native::NativeEngine;
use dslsh::engine::Metric;
use dslsh::experiments::report::Table;
use dslsh::knn::exhaustive::pknn_query_batch;
use dslsh::lsh::family::LayerSpec;
use dslsh::metrics::Confusion;
use dslsh::slsh::SlshParams;
use dslsh::util::stats;

const PROBES: [u32; 4] = [1, 2, 4, 8];

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (corpus points, queries, tables) — few tables on purpose: that is
    // where multi-probe earns its keep (each probe substitutes for a
    // table the index never built).
    let (n, n_queries, l) = if smoke { (3_000, 60, 6) } else { (20_000, 300, 8) };
    let k = 10usize;
    let (nu, p) = (2usize, 2usize);

    println!("== tradeoff bench ({} mode) ==", if smoke { "smoke" } else { "full" });
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), n, n_queries, 42));
    let (lo, hi) = corpus.data.value_range();
    let params =
        SlshParams::lsh_only(LayerSpec::outer_l1(corpus.data.dim, 40, l, lo, hi, 7), k);
    let cluster = build_cluster(&corpus.data, &params, &ClusterConfig::new(nu, p))
        .expect("cluster build");

    // Exhaustive L1 ground truth over the FULL corpus — the recall
    // yardstick every probe count is measured against.
    println!("computing exhaustive ground truth ({n} points x {n_queries} queries)...");
    let engine = NativeEngine::new();
    let exact = pknn_query_batch(
        &engine,
        Metric::L1,
        &corpus.queries.points,
        &corpus.data.points,
        corpus.data.dim,
        &corpus.data.labels,
        k,
        nu * p,
    );
    let exact_ids: Vec<Vec<u64>> =
        exact.iter().map(|r| r.neighbors.iter().map(|nb| nb.id).collect()).collect();

    let mut table = Table::new(
        format!("Multi-probe tradeoff — nu={nu} x p={p}, m=40 L={l}, recall@{k} vs exhaustive L1"),
        &["probes", "median max comps", "total comps", "recall", "mcc", "p50 ms"],
    );

    let mut totals: Vec<u64> = Vec::new();
    for probes in PROBES {
        let spec = QuerySpec::new().with_probes(probes);
        let mut max_comps = Vec::with_capacity(n_queries);
        let mut lat_ms = Vec::with_capacity(n_queries);
        let mut total = 0u64;
        let mut hits = 0usize;
        let mut confusion = Confusion::new();
        for i in 0..corpus.queries.len() {
            let t = Instant::now();
            let r = cluster.query_spec(corpus.queries.point(i), &spec).expect("query");
            lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
            max_comps.push(r.max_comparisons as f64);
            total += r.per_node_comparisons.iter().flatten().sum::<u64>();
            hits += r
                .neighbors
                .iter()
                .filter(|nb| exact_ids[i].contains(&nb.id))
                .count();
            confusion.push(r.prediction, corpus.queries.labels[i]);
        }
        let recall = hits as f64 / (corpus.queries.len() * k) as f64;
        println!(
            "probes {probes}: median max comps {:.0}, total {total}, recall@{k} {recall:.3}, \
             mcc {:.3}, p50 {:.2} ms",
            stats::median(&max_comps),
            confusion.mcc(),
            stats::percentile(&lat_ms, 0.50),
        );
        table.row(vec![
            probes.to_string(),
            format!("{:.0}", stats::median(&max_comps)),
            total.to_string(),
            format!("{recall:.4}"),
            format!("{:.4}", confusion.mcc()),
            format!("{:.3}", stats::percentile(&lat_ms, 0.50)),
        ]);
        totals.push(total);
    }

    println!();
    println!("{}", table.render());
    table.save(std::path::Path::new("results"), "tradeoff").expect("saving csv");
    println!("saved results/tradeoff.csv");

    if smoke {
        let csv = std::fs::read_to_string("results/tradeoff.csv")
            .expect("results/tradeoff.csv must exist");
        assert!(
            csv.lines().count() >= 1 + PROBES.len(),
            "smoke: tradeoff.csv must hold every probe row:\n{csv}"
        );
        for w in totals.windows(2) {
            assert!(
                w[1] > w[0],
                "smoke: total comparisons must be STRICTLY increasing in probes ({totals:?})"
            );
        }
        println!(
            "smoke OK: tradeoff.csv has {} lines, comparisons strictly increasing {totals:?}",
            csv.lines().count()
        );
    }
}
