//! Regenerates **Figure 4** (SLSH inner layer at the onset m_out=125,
//! L_out=120: m_in x L_in grid, alpha=0.005). DSLSH_BENCH_SCALE to resize.

use dslsh::experiments::harness::{seed_from_env, Scale};
use dslsh::experiments::tradeoff::{run_fig4, TradeoffOptions};

fn main() {
    let t0 = std::time::Instant::now();
    let opts = TradeoffOptions::paper_defaults(Scale::from_env(), seed_from_env());
    let r = run_fig4(&opts).expect("fig4 failed");
    println!("{}", r.scatter);
    println!("PKNN: {} comps/proc, MCC = {:.3}", r.pknn_comps, r.pknn_mcc);
    println!("{}", r.table.render());
    r.table.save(std::path::Path::new("results"), "fig4").expect("saving results");
    println!("[fig4_slsh] done in {:.1}s -> results/fig4.csv", t0.elapsed().as_secs_f64());
}
