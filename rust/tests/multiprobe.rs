//! Multi-probe / `QuerySpec` integration suite: the per-request
//! accuracy/latency control plane end to end.
//!
//! What is pinned here, layer by layer:
//!
//! * **Baseline identity.** `probes = 1` with no comparison cap is THE
//!   pre-spec behavior, bit-identical at the node (`query_batch` vs
//!   `query_batch_spec` with baseline knobs), the orchestrator
//!   (`query` vs `query_spec(default)`), across the wire (a default
//!   spec rides the plain `QueryBatch` frame), and over HTTP (a body
//!   with no knobs equals one with `probes:1, max_comparisons:0`).
//! * **Monotonicity.** Probe sequences are prefixes of each other
//!   (see `lsh::probe`), so widening `probes` can only grow the
//!   candidate set: comparisons and returned-neighbor counts are
//!   non-decreasing in P at the cluster level.
//! * **Determinism of the cap.** `max_comparisons` is a clock-free
//!   per-worker candidate budget: capped runs are reproducible
//!   bit-for-bit, bounded by the cap, and flagged `partial` when the
//!   cap binds — unlike a deadline, identical under any scheduler.
//! * **One spec, every door.** The same `QuerySpec` produces the same
//!   answer through the direct door, the admission queue (knobs ride
//!   the cut), a TCP `RemoteNode` (knobs ride the `QueryBatchBudget`
//!   frame), and the HTTP edge (knobs ride JSON) — and invalid specs
//!   are rejected with typed errors at the validating edges.

mod common;

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use common::{
    assert_bit_identical, corpus, http_post, lsh_params, reference_orchestrator, spawn_replica,
    tcp_cluster,
};
use dslsh::coordinator::admission::{Budget, Class};
use dslsh::coordinator::{AdmissionConfig, BudgetPolicy, QuerySpec};
use dslsh::data::Corpus;
use dslsh::lsh::probe::{ProbeSpec, MAX_PROBES};
use dslsh::net::{EdgeConfig, EdgeServer};
use dslsh::util::json::Json;

/// Flatten the first `nq` query points into one dispatch payload.
fn flat_queries(c: &Corpus, nq: usize) -> Arc<Vec<f32>> {
    let mut flat = Vec::with_capacity(nq * c.queries.dim);
    for i in 0..nq {
        flat.extend_from_slice(c.queries.point(i));
    }
    Arc::new(flat)
}

/// Total scan work in a result, across every node and core.
fn total_comparisons(r: &dslsh::coordinator::QueryResult) -> u64 {
    r.per_node_comparisons.iter().flatten().sum()
}

// ---------------------------------------------------------------------------
// Baseline identity
// ---------------------------------------------------------------------------

/// Node layer: baseline spec knobs dispatch into the literally-unchanged
/// plain batch body — replies match field for field.
#[test]
fn node_baseline_spec_matches_plain_batch() {
    let c = corpus(2_000, 6, 11);
    let params = lsh_params(&c.data, 24, 8, 7);
    let shard = Arc::new(c.data.shard(0..c.data.len()));
    let mut plain_node = spawn_replica(&shard, 0, 0, &params, 2);
    let mut spec_node = spawn_replica(&shard, 0, 0, &params, 2);
    let qs = flat_queries(&c, c.queries.len());
    let plain = plain_node.query_batch(Arc::clone(&qs), c.queries.len());
    let spec = spec_node.query_batch_spec(
        Arc::clone(&qs),
        c.queries.len(),
        Budget::none(),
        Class::Monitor,
        ProbeSpec::BASELINE,
    );
    assert_eq!(plain.len(), spec.len());
    for (p, s) in plain.iter().zip(&spec) {
        assert_eq!(p.neighbors, s.neighbors, "qid {}: neighbors", p.qid);
        assert_eq!(p.comparisons, s.comparisons, "qid {}: comparisons", p.qid);
        assert_eq!(p.inner_probes, s.inner_probes, "qid {}: inner_probes", p.qid);
        assert!(!s.partial && !s.shed, "baseline spec must not truncate");
    }
}

/// Cluster layer: `QuerySpec::default()` through the spec door equals the
/// positional `query` path bit for bit.
#[test]
fn cluster_default_spec_matches_query() {
    let c = corpus(3_000, 8, 21);
    let params = lsh_params(&c.data, 24, 8, 7);
    let orch = reference_orchestrator(&c.data, &params, 2, 2);
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        let want = orch.query(q).unwrap();
        let got = orch.query_spec(q, &QuerySpec::default()).unwrap();
        assert_bit_identical(&got, &want, &format!("default spec, query {i}"));
        let explicit = orch
            .query_spec(q, &QuerySpec::new().with_probes(1).with_max_comparisons(0))
            .unwrap();
        assert_bit_identical(&explicit, &want, &format!("explicit baseline, query {i}"));
    }
}

// ---------------------------------------------------------------------------
// Monotonicity in P
// ---------------------------------------------------------------------------

/// Probe sequences are prefixes, so work and recall can only grow with P:
/// total comparisons and neighbor counts are non-decreasing, and every
/// run at the same P is reproducible.
#[test]
fn candidates_grow_monotonically_with_probes() {
    let c = corpus(3_000, 6, 31);
    let params = lsh_params(&c.data, 24, 8, 7);
    let orch = reference_orchestrator(&c.data, &params, 2, 2);
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        let mut prev_work = 0u64;
        let mut prev_neighbors = 0usize;
        for probes in [1u32, 2, 4, 8, 16] {
            let spec = QuerySpec::new().with_probes(probes);
            let r = orch.query_spec(q, &spec).unwrap();
            let again = orch.query_spec(q, &spec).unwrap();
            assert_bit_identical(&again, &r, &format!("query {i} probes {probes} rerun"));
            let work = total_comparisons(&r);
            assert!(
                work >= prev_work,
                "query {i}: comparisons shrank at probes={probes} ({work} < {prev_work})"
            );
            assert!(
                r.neighbors.len() >= prev_neighbors,
                "query {i}: neighbor count shrank at probes={probes}"
            );
            assert!(!r.partial, "no cap, no deadline: nothing may truncate");
            prev_work = work;
            prev_neighbors = r.neighbors.len();
        }
    }
}

// ---------------------------------------------------------------------------
// The deterministic comparison cap
// ---------------------------------------------------------------------------

/// `max_comparisons` binds per worker, reproducibly: capped runs are
/// bit-identical to each other, respect the bound, and flag `partial` —
/// with no clock anywhere in the decision.
#[test]
fn comparison_cap_is_deterministic_bounded_and_flagged() {
    let c = corpus(3_000, 4, 41);
    let params = lsh_params(&c.data, 24, 8, 7);
    let orch = reference_orchestrator(&c.data, &params, 2, 2);
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        let full = orch.query_spec(q, &QuerySpec::new().with_probes(8)).unwrap();
        // A cap well under the uncapped max is guaranteed to bind on the
        // busiest worker.
        let cap = (full.max_comparisons / 4).max(1);
        let spec = QuerySpec::new().with_probes(8).with_max_comparisons(cap);
        let a = orch.query_spec(q, &spec).unwrap();
        let b = orch.query_spec(q, &spec).unwrap();
        assert_bit_identical(&b, &a, &format!("query {i} capped rerun"));
        assert!(
            a.max_comparisons <= cap,
            "query {i}: cap {cap} exceeded ({})",
            a.max_comparisons
        );
        assert!(a.partial, "query {i}: a binding cap must flag partial");
    }
}

/// `k` trims the returned list without touching the vote: prediction and
/// positive share match the untrimmed run exactly.
#[test]
fn k_caps_returned_neighbors_but_not_the_vote() {
    let c = corpus(2_000, 4, 51);
    let params = lsh_params(&c.data, 24, 8, 7);
    let orch = reference_orchestrator(&c.data, &params, 2, 2);
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        let full = orch.query_spec(q, &QuerySpec::new().with_probes(4)).unwrap();
        let trimmed =
            orch.query_spec(q, &QuerySpec::new().with_probes(4).with_k(3)).unwrap();
        assert!(trimmed.neighbors.len() <= 3, "query {i}: k=3 not honored");
        assert_eq!(
            trimmed.neighbors[..],
            full.neighbors[..trimmed.neighbors.len()],
            "query {i}: trimmed list must be a prefix of the full K-NN"
        );
        assert_eq!(trimmed.prediction, full.prediction, "query {i}: vote changed by k");
        assert!(
            trimmed.positive_share == full.positive_share,
            "query {i}: positive_share changed by k"
        );
    }
}

// ---------------------------------------------------------------------------
// Spec validation and the recall dial
// ---------------------------------------------------------------------------

#[test]
fn recall_hint_maps_to_the_documented_probe_ladder() {
    assert_eq!(QuerySpec::new().requested_probes(), 0, "unset = auto");
    assert_eq!(QuerySpec::new().with_recall_hint(0.3).requested_probes(), 1);
    assert_eq!(QuerySpec::new().with_recall_hint(0.5).requested_probes(), 1);
    assert_eq!(QuerySpec::new().with_recall_hint(0.75).requested_probes(), 2);
    assert_eq!(QuerySpec::new().with_recall_hint(0.9).requested_probes(), 4);
    assert_eq!(QuerySpec::new().with_recall_hint(1.0).requested_probes(), 8);
    assert_eq!(QuerySpec::new().with_probes(6).requested_probes(), 6);
}

#[test]
fn spec_validation_rejects_conflicts_and_out_of_range_knobs() {
    assert!(QuerySpec::new().validate().is_ok());
    assert!(QuerySpec::new()
        .with_probes(8)
        .with_max_comparisons(100)
        .with_k(3)
        .validate()
        .is_ok());
    assert!(QuerySpec::new().with_probes(MAX_PROBES).validate().is_ok());
    // probes and recall_hint are two dials for the same knob.
    assert!(QuerySpec::new().with_probes(2).with_recall_hint(0.9).validate().is_err());
    assert!(QuerySpec::new().with_probes(MAX_PROBES + 1).validate().is_err());
    assert!(QuerySpec::new().with_recall_hint(0.0).validate().is_err());
    assert!(QuerySpec::new().with_recall_hint(1.5).validate().is_err());
    assert!(QuerySpec::new().with_recall_hint(f32::NAN).validate().is_err());
}

// ---------------------------------------------------------------------------
// The admission door
// ---------------------------------------------------------------------------

/// The same spec answered through the admission queue equals the direct
/// door bit for bit: knobs survive the cut resolution (a solo rider's
/// probes/cap are its own maxima/minima), and `LogOnly` keeps the
/// deadline observational so timing cannot perturb the comparison.
#[test]
fn admission_door_matches_direct_door_for_the_same_spec() {
    let c = corpus(2_000, 6, 61);
    let params = lsh_params(&c.data, 24, 8, 7);
    let mut orch = reference_orchestrator(&c.data, &params, 2, 2);
    let spec = QuerySpec::new()
        .with_probes(4)
        .with_max_comparisons(400)
        .with_budget(Duration::from_millis(2))
        .with_policy(BudgetPolicy::LogOnly);
    let direct: Vec<_> = (0..c.queries.len())
        .map(|i| orch.query_spec(c.queries.point(i), &spec).unwrap())
        .collect();
    orch.enable_admission(
        AdmissionConfig::new(c.data.dim, 8).with_budget_policy(BudgetPolicy::LogOnly),
    );
    for i in 0..c.queries.len() {
        let ticket = orch.submit_spec(c.queries.point(i), &spec).unwrap();
        let r = ticket.wait().unwrap();
        assert_bit_identical(&r, &direct[i], &format!("admitted query {i}"));
    }
}

// ---------------------------------------------------------------------------
// The wire
// ---------------------------------------------------------------------------

/// Spec knobs cross a real TCP hop bit-identically — and a default spec
/// rides the plain pre-spec frame, so turning no knob changes no byte of
/// wire traffic.
#[test]
fn spec_knobs_cross_the_wire_bit_identically() {
    let c = corpus(2_000, 4, 71);
    let params = lsh_params(&c.data, 24, 8, 7);
    let local = reference_orchestrator(&c.data, &params, 2, 2);
    let (remote, servers) = tcp_cluster(&c.data, &params, 2, 2);
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        assert_bit_identical(
            &remote.query_spec(q, &QuerySpec::default()).unwrap(),
            &local.query(q).unwrap(),
            &format!("default spec over TCP, query {i}"),
        );
        let spec = QuerySpec::new().with_probes(4).with_max_comparisons(300);
        assert_bit_identical(
            &remote.query_spec(q, &spec).unwrap(),
            &local.query_spec(q, &spec).unwrap(),
            &format!("probed+capped spec over TCP, query {i}"),
        );
    }
    drop(remote);
    for s in servers {
        s.join().unwrap();
    }
}

// ---------------------------------------------------------------------------
// The HTTP edge
// ---------------------------------------------------------------------------

/// The workload-determined slice of a query response body (qid and
/// latency are scheduler/wall-clock and excluded, exactly as
/// `assert_bit_identical` does in-process).
fn body_essence(r: &common::HttpResponse) -> Vec<(&'static str, Json)> {
    let j = r.json();
    ["prediction", "positive_share", "partial", "shed_nodes", "max_comparisons", "neighbors",
     "per_node_comparisons"]
        .iter()
        .map(|k| (*k, j.get(k).unwrap_or_else(|| panic!("missing {k} in {:?}", r.body)).clone()))
        .collect()
}

#[test]
fn http_spec_fields_round_trip_and_baseline_matches_plain() {
    let c = corpus(2_000, 2, 81);
    let params = lsh_params(&c.data, 24, 8, 7);
    let orch = Arc::new(reference_orchestrator(&c.data, &params, 2, 2));
    let edge = EdgeServer::start(
        Arc::clone(&orch),
        TcpListener::bind("127.0.0.1:0").unwrap(),
        EdgeConfig::new(c.data.dim),
    )
    .unwrap();
    let addr = edge.addr();
    let pt = c
        .queries
        .point(0)
        .iter()
        .map(|v| format!("{v}"))
        .collect::<Vec<_>>()
        .join(",");

    // No knobs == explicit baseline knobs, field for field.
    let plain = http_post(addr, "/v1/query", &format!("{{\"point\":[{pt}]}}"));
    assert_eq!(plain.status, 200, "{:?}", plain.body);
    let explicit = http_post(
        addr,
        "/v1/query",
        &format!("{{\"point\":[{pt}],\"probes\":1,\"max_comparisons\":0}}"),
    );
    assert_eq!(explicit.status, 200, "{:?}", explicit.body);
    assert_eq!(body_essence(&plain), body_essence(&explicit));
    // ... and both equal the in-process answer.
    let want = orch.query(c.queries.point(0)).unwrap();
    let got = plain.json();
    assert_eq!(got.get("max_comparisons").and_then(|v| v.as_u64()), Some(want.max_comparisons));
    assert_eq!(
        got.get("neighbors").map(|n| n.as_arr().unwrap().len()),
        Some(want.neighbors.len())
    );

    // Widening probes over JSON grows the scan.
    let p8 = http_post(addr, "/v1/query", &format!("{{\"point\":[{pt}],\"probes\":8}}"));
    assert_eq!(p8.status, 200, "{:?}", p8.body);
    let p8_max = p8.json().get("max_comparisons").and_then(|v| v.as_u64()).unwrap();
    assert!(p8_max >= want.max_comparisons, "probes=8 must not shrink the scan");

    // A binding cap truncates deterministically and surfaces as a 206.
    let cap = (p8_max / 4).max(1);
    let capped = http_post(
        addr,
        "/v1/query",
        &format!("{{\"point\":[{pt}],\"probes\":8,\"max_comparisons\":{cap}}}"),
    );
    assert_eq!(capped.status, 206, "a binding cap is a flagged partial: {:?}", capped.body);
    let cj = capped.json();
    assert_eq!(cj.get("partial"), Some(&Json::Bool(true)));
    assert!(cj.get("max_comparisons").and_then(|v| v.as_u64()).unwrap() <= cap);

    // k trims the returned list.
    let k2 = http_post(addr, "/v1/query", &format!("{{\"point\":[{pt}],\"k\":2}}"));
    assert_eq!(k2.status, 200, "{:?}", k2.body);
    assert!(k2.json().get("neighbors").unwrap().as_arr().unwrap().len() <= 2);

    // recall_hint is accepted as the declarative dial.
    let hinted =
        http_post(addr, "/v1/query", &format!("{{\"point\":[{pt}],\"recall_hint\":0.9}}"));
    assert_eq!(hinted.status, 200, "{:?}", hinted.body);
}

#[test]
fn http_rejects_invalid_specs_with_typed_errors() {
    let c = corpus(500, 1, 91);
    let params = lsh_params(&c.data, 24, 4, 7);
    let orch = Arc::new(reference_orchestrator(&c.data, &params, 1, 1));
    let edge = EdgeServer::start(
        Arc::clone(&orch),
        TcpListener::bind("127.0.0.1:0").unwrap(),
        EdgeConfig::new(c.data.dim),
    )
    .unwrap();
    let addr = edge.addr();
    let pt = c
        .queries
        .point(0)
        .iter()
        .map(|v| format!("{v}"))
        .collect::<Vec<_>>()
        .join(",");
    // (body fragment after "point", expected error code)
    let cases: &[(&str, &str)] = &[
        // Cross-field validation: two dials for one knob, range checks.
        ("\"probes\":2,\"recall_hint\":0.9", "bad-spec"),
        ("\"probes\":65537", "bad-spec"),
        ("\"recall_hint\":0.0", "bad-spec"),
        ("\"recall_hint\":1.5", "bad-spec"),
        // Field-level type errors.
        ("\"probes\":true", "bad-probes"),
        ("\"probes\":1.5", "bad-probes"),
        ("\"probes\":-1", "bad-probes"),
        ("\"recall_hint\":\"high\"", "bad-recall-hint"),
        ("\"max_comparisons\":\"many\"", "bad-max-comparisons"),
        ("\"k\":-1", "bad-k"),
        // Unknown knobs stay a hard error — no silent typo acceptance.
        ("\"probez\":4", "unknown-field"),
    ];
    for (frag, code) in cases {
        let r = http_post(addr, "/v1/query", &format!("{{\"point\":[{pt}],{frag}}}"));
        assert_eq!(r.status, 400, "{frag}: {:?}", r.body);
        assert_eq!(r.error_code(), *code, "{frag}");
    }
    // A valid spec on the same server still serves — rejection is
    // per-request, not connection-poisoning.
    let ok = http_post(addr, "/v1/query", &format!("{{\"point\":[{pt}],\"probes\":2}}"));
    assert!(ok.status == 200 || ok.status == 206, "{:?}", ok.body);
}
