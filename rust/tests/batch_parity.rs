//! Batched-pipeline parity: every batched entry point must return
//! results IDENTICAL (bit-for-bit on distances) to its sequential
//! counterpart, across metrics, batch sizes (including 1 and
//! non-multiples of the kernel tiles), and shard/core partitionings.
//!
//! The batched path is a pure performance lever — these tests are the
//! contract that it never changes an answer.

use dslsh::coordinator::{build_cluster, ClusterConfig};
use dslsh::data::{build_corpus, Corpus, CorpusConfig, WindowSpec};
use dslsh::engine::native::NativeEngine;
use dslsh::engine::{DistanceEngine, Metric};
use dslsh::knn::exhaustive::{pknn_query, pknn_query_batch};
use dslsh::knn::TopK;
use dslsh::lsh::family::LayerSpec;
use dslsh::slsh::{BatchOutput, QueryScratch, SlshIndex, SlshParams};
use dslsh::util::rng::Xoshiro256;
use dslsh::util::stamp::StampSet;

fn corpus() -> Corpus {
    build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), 4000, 60, 91))
}

/// Engine-level: scan_batch over an arbitrary id list == per-query scan,
/// exactly, for both metrics and a sweep of batch sizes.
#[test]
fn engine_scan_batch_parity_sweep() {
    let dim = 30;
    let mut rng = Xoshiro256::seed_from_u64(1);
    let n = 2000;
    let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.1)).collect();
    let engine = NativeEngine::new();
    let ids: Vec<u32> = (0..n as u32).filter(|i| i % 3 != 0).collect();
    for metric in [Metric::L1, Metric::Cosine] {
        for nq in [1usize, 2, 3, 4, 5, 8, 13, 32] {
            let qs: Vec<f32> =
                (0..nq * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
            let mut batched: Vec<TopK> = (0..nq).map(|_| TopK::new(10)).collect();
            let total = engine.scan_batch(metric, &qs, &data, dim, &ids, &labels, 0, &mut batched);
            assert_eq!(total, (nq * ids.len()) as u64);
            for qi in 0..nq {
                let mut seq = TopK::new(10);
                let c = engine.scan(
                    metric,
                    &qs[qi * dim..(qi + 1) * dim],
                    &data,
                    dim,
                    &ids,
                    &labels,
                    0,
                    &mut seq,
                );
                assert_eq!(c, ids.len() as u64);
                assert_eq!(
                    batched[qi].clone().into_sorted(),
                    seq.into_sorted(),
                    "metric={metric:?} nq={nq} qi={qi}"
                );
            }
        }
    }
}

/// PKNN: batched exhaustive results equal sequential for every metric,
/// batch size and processor partitioning.
#[test]
fn pknn_batch_parity_across_partitionings() {
    let c = corpus();
    let engine = NativeEngine::new();
    let dim = c.data.dim;
    for metric in [Metric::L1, Metric::Cosine] {
        for procs in [1usize, 3, 8, 13] {
            for nq in [1usize, 4, 7] {
                let block = &c.queries.points[..nq * dim];
                let batch = pknn_query_batch(
                    &engine, metric, block, &c.data.points, dim, &c.data.labels, 10, procs,
                );
                for qi in 0..nq {
                    let seq = pknn_query(
                        &engine,
                        metric,
                        c.queries.point(qi),
                        &c.data.points,
                        dim,
                        &c.data.labels,
                        10,
                        procs,
                    );
                    assert_eq!(
                        batch[qi].neighbors, seq.neighbors,
                        "metric={metric:?} procs={procs} nq={nq} qi={qi}"
                    );
                    assert_eq!(batch[qi].comparisons, seq.comparisons);
                }
            }
        }
    }
}

/// Index-level: query_batch == query across LSH-only and stratified
/// parameterizations AND across table partitionings (each core's table
/// subset resolves batches identically to its sequential path).
#[test]
fn slsh_index_batch_parity_across_table_shards() {
    let c = corpus();
    let (lo, hi) = c.data.value_range();
    let params = SlshParams::lsh_only(LayerSpec::outer_l1(c.data.dim, 36, 12, lo, hi, 3), 10);
    let engine = NativeEngine::new();
    for p in [1usize, 4] {
        for core in 0..p {
            let mine: Vec<usize> = (0..12).filter(|t| t % p == core).collect();
            let idx = SlshIndex::build(&params, &c.data, &mine);
            let mut scratch = QueryScratch::new(c.data.len());
            let mut out = BatchOutput::new();
            let mut visited = StampSet::new(c.data.len());
            let mut cand = Vec::new();
            for nq in [1usize, 5, 6] {
                let block = &c.queries.points[..nq * c.data.dim];
                idx.query_batch(
                    &engine,
                    block,
                    &c.data.points,
                    &c.data.labels,
                    0,
                    &mut scratch,
                    &mut out,
                );
                for qi in 0..nq {
                    let seq = idx.query(
                        &engine,
                        c.queries.point(qi),
                        &c.data.points,
                        &c.data.labels,
                        0,
                        &mut visited,
                        &mut cand,
                    );
                    assert_eq!(out.stats(qi), seq.stats, "p={p} core={core} qi={qi}");
                    assert_eq!(out.neighbors(qi), seq.topk.into_sorted().as_slice());
                }
            }
        }
    }
}

/// Cluster-level: the Orchestrator's batched admission returns the same
/// neighbors, predictions and comparison counts as sequential queries,
/// across (ν, p) topologies.
#[test]
fn cluster_query_batch_parity_across_topologies() {
    let c = corpus();
    let (lo, hi) = c.data.value_range();
    let params = SlshParams::lsh_only(LayerSpec::outer_l1(c.data.dim, 40, 16, lo, hi, 13), 10);
    for (nu, p) in [(1usize, 1usize), (2, 2), (3, 1)] {
        let cluster = build_cluster(&c.data, &params, &ClusterConfig::new(nu, p)).unwrap();
        // Sequential reference.
        let sequential: Vec<_> = (0..24).map(|i| cluster.query(c.queries.point(i)).unwrap()).collect();
        // Batched, in blocks of 1 / 7 / 16 (stragglers included).
        let mut batched = Vec::new();
        for block in [(0usize, 1usize), (1, 8), (8, 24)] {
            let qs: Vec<&[f32]> = (block.0..block.1).map(|i| c.queries.point(i)).collect();
            batched.extend(cluster.query_batch(&qs).unwrap());
        }
        assert_eq!(batched.len(), sequential.len());
        for (i, (b, s)) in batched.iter().zip(&sequential).enumerate() {
            assert_eq!(b.neighbors, s.neighbors, "nu={nu} p={p} query {i}");
            assert_eq!(b.prediction, s.prediction);
            assert!((b.positive_share - s.positive_share).abs() < 1e-12);
            assert_eq!(b.max_comparisons, s.max_comparisons);
            assert_eq!(b.per_node_comparisons, s.per_node_comparisons);
        }
    }
}
