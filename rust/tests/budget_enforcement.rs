//! Deterministic node-side budget-enforcement tests: the deadline is an
//! ENFORCED contract from the scan kernel to the ticket, not a telemetry
//! footnote.
//!
//! What is proven, all MockClock/TickClock-driven and
//! handshake-synchronized (no sleeps, no machine-speed assumptions):
//!
//! * **(a) Blown budget ⇒ partial, with monotone work.** A budget that is
//!   already spent yields `partial = true` with ZERO candidates examined
//!   — strictly fewer than the unenforced run — and across a deadline
//!   sweep the work done is monotonically non-decreasing in the budget,
//!   never exceeding the unenforced run.
//! * **(b) Partial answers are strict prefixes.** An enforced answer is
//!   reconstructed bit-for-bit as the unenforced resolution of the first
//!   `tables` owned tables truncated to the first `comparisons`
//!   candidates — and every returned neighbor appears in the unenforced
//!   run's candidate walk with its true distance. Partials are prefixes,
//!   never samples.
//! * **(c) `LogOnly` is bit-identical to the pre-enforcement behavior**,
//!   node-level and end-to-end through the admission queue.
//! * **`Shed` rejects before ANY scan work** when the budget is spent on
//!   arrival — and behaves like `PartialResults` when budget remains.
//! * **The remaining budget is computed once, at dispatch** (a slow
//!   MockClock step between cut and dispatch is charged against the
//!   budget), and local and remote (TCP) nodes enforce that same shipped
//!   value identically.

// The positional submit/query entry points are deprecated shims over the
// QuerySpec API; this file exercises them on purpose (they must keep
// working bit-identically until removal).
#![allow(deprecated)]

mod common;

use std::collections::HashSet;
use std::net::TcpListener;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use common::{assert_bit_identical, corpus, echo_result, lsh_params, native_engines, wait_until};
use dslsh::coordinator::admission::{
    AdmissionConfig, AdmissionQueue, Budget, BudgetPolicy, Class, Clock, MockClock, TickClock,
};
use dslsh::coordinator::orchestrator::{NodeHandle, Orchestrator};
use dslsh::coordinator::{build_cluster, ClusterConfig};
use dslsh::engine::native::NativeEngine;
use dslsh::engine::{DistanceEngine, Metric, ScanCancel};
use dslsh::knn::heap::TopK;
use dslsh::knn::predict::VoteConfig;
use dslsh::net::{serve_node, RemoteNode};
use dslsh::node::node::{LocalNode, NodeReply};
use dslsh::slsh::{BatchOutput, QueryScratch, SlshIndex};
use dslsh::util::stamp::StampSet;
use dslsh::util::threadpool::chunk_ranges;

/// Flat row-major block of dataset points (self-queries guarantee every
/// query collides in every table, so the unenforced run always does
/// work).
fn self_queries(data: &dslsh::data::Dataset, ids: &[usize]) -> Vec<f32> {
    let mut flat = Vec::with_capacity(ids.len() * data.dim);
    for &i in ids {
        flat.extend_from_slice(data.point(i));
    }
    flat
}

// ---------------------------------------------------------------------------
// (a) + (b): index-level, TickClock-driven partial scans
// ---------------------------------------------------------------------------

#[test]
fn partial_scans_are_monotone_table_prefixes_of_the_full_answer() {
    let c = corpus(1500, 6, 21);
    let dim = c.data.dim;
    let p = lsh_params(&c.data, 24, 12, 7);
    let idx = SlshIndex::build_full(&p, &c.data);
    let engine = NativeEngine::new();
    let mut scratch = QueryScratch::new(c.data.len());
    let nq = 4usize;
    let qs = self_queries(&c.data, &[3, 77, 500, 1200]);

    // Unenforced reference (and the enforced path with an unbounded
    // token, which must be bit-identical to it).
    let mut full = BatchOutput::new();
    idx.query_batch(&engine, &qs, &c.data.points, &c.data.labels, 0, &mut scratch, &mut full);
    let mut unbounded_out = BatchOutput::new();
    let unbounded = ScanCancel::unbounded(Arc::new(MockClock::new(0)));
    idx.query_batch_cancel(
        &engine,
        &qs,
        &c.data.points,
        &c.data.labels,
        0,
        &mut scratch,
        &mut unbounded_out,
        &unbounded,
    );
    for qi in 0..nq {
        assert_eq!(unbounded_out.stats(qi), full.stats(qi), "qi={qi}");
        assert_eq!(unbounded_out.neighbors(qi), full.neighbors(qi), "qi={qi}");
        assert!(full.stats(qi).comparisons > 0, "fixture must do work for qi={qi}");
    }

    // Full candidate walks (per query) for the ⊆-of-unenforced-run check.
    let mut visited = StampSet::new(c.data.len());
    let mut cand = Vec::new();
    let full_candidates: Vec<HashSet<u32>> = (0..nq)
        .map(|qi| {
            idx.candidates(&qs[qi * dim..(qi + 1) * dim], &mut visited, &mut cand);
            cand.iter().copied().collect()
        })
        .collect();

    // Deadline sweep on a TickClock (1ns per clock read): every run is a
    // pure function of the deadline. Work must be monotone in the budget
    // and every partial answer must reconstruct as a strict prefix.
    let mut prev = vec![0u64; nq];
    let mut saw_partial_with_work = false;
    for deadline in [0u64, 1, 2, 3, 5, 8, 13, 21, 40, 80, 1_000, 1_000_000] {
        let cancel = ScanCancel::until(Arc::new(TickClock::new(0, 1)), deadline);
        let mut out = BatchOutput::new();
        idx.query_batch_cancel(
            &engine,
            &qs,
            &c.data.points,
            &c.data.labels,
            0,
            &mut scratch,
            &mut out,
            &cancel,
        );
        for qi in 0..nq {
            let st = out.stats(qi);
            let full_st = full.stats(qi);
            assert!(st.comparisons <= full_st.comparisons, "d={deadline} qi={qi}");
            assert!(st.tables <= full_st.tables, "d={deadline} qi={qi}");
            assert!(
                st.comparisons >= prev[qi],
                "work must be monotone in the budget: d={deadline} qi={qi}"
            );
            prev[qi] = st.comparisons;
            if deadline == 0 {
                // (a) already-blown budget: flagged, and STRICTLY fewer
                // candidates examined than the unenforced run (zero).
                assert!(st.partial, "qi={qi}");
                assert_eq!(st.comparisons, 0);
                assert_eq!(st.tables, 0);
                assert!(out.neighbors(qi).is_empty());
            }
            if !st.partial {
                assert_eq!(st, full_st, "complete answers must match the unenforced run");
                assert_eq!(out.neighbors(qi), full.neighbors(qi));
            } else {
                assert!(
                    st.comparisons < full_st.comparisons || st.tables < full_st.tables,
                    "a partial answer must have done less: d={deadline} qi={qi}"
                );
                if st.comparisons > 0 {
                    saw_partial_with_work = true;
                }
                // (b) strict-prefix reconstruction: an index holding only
                // the first `tables` owned tables, resolved WITHOUT
                // enforcement and truncated to the first `comparisons`
                // candidates, reproduces the partial answer bit-for-bit.
                let prefix_tables: Vec<usize> = (0..st.tables as usize).collect();
                let prefix_idx = SlshIndex::build(&p, &c.data, &prefix_tables);
                let q = &qs[qi * dim..(qi + 1) * dim];
                prefix_idx.candidates(q, &mut visited, &mut cand);
                assert!(
                    st.comparisons as usize <= cand.len(),
                    "d={deadline} qi={qi}: examined more than the prefix holds"
                );
                let mut topk = TopK::new(p.k);
                engine.scan(
                    Metric::L1,
                    q,
                    &c.data.points,
                    dim,
                    &cand[..st.comparisons as usize],
                    &c.data.labels,
                    0,
                    &mut topk,
                );
                assert_eq!(
                    out.neighbors(qi),
                    topk.into_sorted().as_slice(),
                    "d={deadline} qi={qi}: partial answer must be the prefix resolution"
                );
                // ...and every returned neighbor appears in the
                // unenforced run's candidate walk.
                for n in out.neighbors(qi) {
                    assert!(
                        full_candidates[qi].contains(&(n.id as u32)),
                        "d={deadline} qi={qi}: neighbor {} not in the unenforced run",
                        n.id
                    );
                }
            }
        }
    }
    assert!(
        saw_partial_with_work,
        "sweep must include genuine mid-scan partials, not only empty/complete runs"
    );
}

// ---------------------------------------------------------------------------
// Node-level enforcement (MockClock, frozen: deterministic on any machine)
// ---------------------------------------------------------------------------

/// Everything in a `NodeReply` that is workload-determined (`qid` is
/// per-node arrival order, excluded).
fn assert_replies_match(got: &[NodeReply], want: &[NodeReply], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: arity");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.neighbors, w.neighbors, "{ctx} q={i}: neighbors");
        assert_eq!(g.comparisons, w.comparisons, "{ctx} q={i}: comparisons");
        assert_eq!(g.inner_probes, w.inner_probes, "{ctx} q={i}: inner_probes");
        assert_eq!(g.partial, w.partial, "{ctx} q={i}: partial");
        assert_eq!(g.shed, w.shed, "{ctx} q={i}: shed");
    }
}

#[test]
fn node_enforcement_policies_zero_and_slack_budgets() {
    let c = corpus(1200, 4, 33);
    let p = lsh_params(&c.data, 30, 8, 5);
    let shard = Arc::new(c.data.clone());
    let nq = 4usize;
    let qs = Arc::new(self_queries(&c.data, &[1, 200, 600, 1100]));

    // Twin nodes with identical specs build identical tables; `node` runs
    // on a frozen MockClock so every enforcement decision is exact.
    let clock = Arc::new(MockClock::new(10_000));
    let mut node = LocalNode::spawn_with_clock(
        0,
        Arc::clone(&shard),
        0,
        &p,
        2,
        native_engines(2),
        Arc::clone(&clock) as Arc<dyn Clock>,
    );
    let mut twin = LocalNode::spawn(0, Arc::clone(&shard), 0, &p, 2, native_engines(2));
    let full = twin.query_batch(Arc::clone(&qs), nq);
    let full_work: u64 = full.iter().flat_map(|r| r.comparisons.iter()).sum();
    assert!(full_work > 0, "fixture must do work unenforced");

    // (a) PartialResults with the budget already spent: partial replies,
    // ZERO scan work — strictly fewer candidates examined than the
    // unenforced run.
    let replies = node.query_batch_budget(
        Arc::clone(&qs),
        nq,
        Budget::enforced(0, BudgetPolicy::PartialResults),
        Class::Monitor,
    );
    assert_eq!(replies.len(), nq);
    for r in &replies {
        assert!(r.partial && !r.shed);
        assert!(r.neighbors.is_empty());
        assert!(r.comparisons.iter().all(|&w| w == 0), "no scan work on a spent budget");
    }

    // PartialResults with slack budget on a frozen clock: the deadline
    // can never pass, so the answer is bit-identical to the unenforced
    // twin.
    let slack = node.query_batch_budget(
        Arc::clone(&qs),
        nq,
        Budget::enforced(1_000_000, BudgetPolicy::PartialResults),
        Class::Monitor,
    );
    assert_replies_match(&slack, &full, "slack PartialResults");

    // (c) LogOnly is bit-identical to the plain batch path even with a
    // hopeless 1µs budget (it only logs the overrun).
    let log_only = node.query_batch_budget(
        Arc::clone(&qs),
        nq,
        Budget::enforced(1, BudgetPolicy::LogOnly),
        Class::Analytics,
    );
    assert_replies_match(&log_only, &full, "LogOnly");

    // Shed with the budget spent on arrival: rejected before ANY scan
    // work, every reply flagged shed + partial.
    let shed = node.query_batch_budget(
        Arc::clone(&qs),
        nq,
        Budget::enforced(0, BudgetPolicy::Shed),
        Class::Monitor,
    );
    assert_eq!(shed.len(), nq);
    for r in &shed {
        assert!(r.shed && r.partial);
        assert!(r.neighbors.is_empty());
        assert_eq!(r.comparisons, vec![0u64; 2], "shed must do zero scan work");
        assert_eq!(r.inner_probes, 0);
    }

    // Shed with budget remaining serves the batch (PartialResults
    // semantics; complete here because the clock is frozen).
    let served = node.query_batch_budget(
        Arc::clone(&qs),
        nq,
        Budget::enforced(1_000_000, BudgetPolicy::Shed),
        Class::Monitor,
    );
    assert_replies_match(&served, &full, "Shed with remaining budget");

    // And a no-budget batch ignores the policy entirely.
    let unbudgeted = node.query_batch_budget(Arc::clone(&qs), nq, Budget::none(), Class::Monitor);
    assert_replies_match(&unbudgeted, &full, "no budget");
}

// ---------------------------------------------------------------------------
// The dispatch-time budget contract (the RemoteNode regression)
// ---------------------------------------------------------------------------

#[test]
fn remaining_budget_is_computed_at_dispatch_not_at_cut() {
    // Regression for the one-deadline contract: the remaining budget a
    // cut ships is computed when the DISPATCHER picks the cut up, so a
    // slow step between cut and dispatch (here: an explicit MockClock
    // advance while the cut is parked at the pipeline rendezvous) is
    // charged against the budget — every node, local or remote, then
    // anchors the same shipped remainder at its own arrival instant.
    let clock = Arc::new(MockClock::new(0));
    let (evt_tx, evt_rx) = channel::<(Vec<f32>, Budget)>();
    let (gate_tx, gate_rx) = channel::<()>();
    let dispatch = move |flat: Vec<f32>,
                         nq: usize,
                         budget: Budget,
                         _class: Class,
                         _probe: dslsh::lsh::probe::ProbeSpec,
                         _trace: u64| {
        evt_tx.send((flat.clone(), budget)).unwrap();
        gate_rx.recv().unwrap();
        Ok((0..nq).map(|i| echo_result(i as u64, flat[i] as f64)).collect())
    };
    let cfg = AdmissionConfig::new(1, 1)
        .with_queue_cap(16)
        .with_pipeline(1)
        .with_budget_policy(BudgetPolicy::PartialResults);
    let q = AdmissionQueue::start_with_clock(cfg, dispatch, Arc::clone(&clock) as Arc<dyn Clock>);

    // Batch 1 (max_batch = 1 ⇒ singleton fill cuts) is dispatched
    // immediately and gated — the dispatcher is now busy.
    let t1 = q.submit(&[1.0], common::FAR).unwrap();
    let (f1, _) = evt_rx.recv().unwrap();
    assert_eq!(f1, vec![1.0]);

    // Batch 2 is CUT now (t = 0, budget 10µs) but parks at the pipeline
    // rendezvous behind the gated dispatcher.
    let t2 = q.submit(&[2.0], Duration::from_micros(10)).unwrap();
    wait_until(|| q.stats().completed == 2, "cut 2 to park at the rendezvous");

    // The slow step between cut and dispatch.
    clock.advance(Duration::from_micros(4));

    // Release batch 1; the dispatcher picks batch 2 up and computes its
    // remaining budget NOW: 10µs − 4µs, not the 10µs of cut time.
    gate_tx.send(()).unwrap();
    let (f2, b2) = evt_rx.recv().unwrap();
    assert_eq!(f2, vec![2.0]);
    assert_eq!(b2.remaining_us, 6, "remaining budget must be computed at dispatch");
    assert_eq!(b2.policy, BudgetPolicy::PartialResults, "policy must ride the cut");
    gate_tx.send(()).unwrap();
    t1.wait().unwrap();
    t2.wait().unwrap();
}

// ---------------------------------------------------------------------------
// End-to-end: flags and counters through cluster, tickets and wire
// ---------------------------------------------------------------------------

#[test]
fn cluster_policies_flow_to_tickets_and_lane_counters() {
    let c = corpus(2000, 8, 55);
    let dim = c.data.dim;
    let p = lsh_params(&c.data, 40, 12, 13);
    let reference = build_cluster(&c.data, &p, &ClusterConfig::new(2, 2)).unwrap();
    let seq: Vec<_> = (0..4).map(|i| reference.query(c.queries.point(i)).unwrap()).collect();
    let mut cluster = build_cluster(&c.data, &p, &ClusterConfig::new(2, 2)).unwrap();

    // (c) LogOnly (the default policy), zero budget: bit-identical to
    // sequential queries — enforcement off means nothing changes, not
    // even the flags.
    cluster
        .orchestrator
        .enable_admission(AdmissionConfig::new(dim, 4).with_queue_cap(32));
    for (i, want) in seq.iter().enumerate() {
        let got = cluster
            .orchestrator
            .submit(c.queries.point(i), Duration::ZERO)
            .unwrap()
            .wait()
            .unwrap();
        assert!(!got.partial && got.shed_nodes == 0, "LogOnly must never flag");
        assert_bit_identical(&got, want, &format!("LogOnly q={i}"));
    }
    let st = cluster.orchestrator.admission().unwrap().stats();
    assert_eq!(st.monitor.partials, 0);
    assert_eq!(st.monitor.sheds, 0);

    // PartialResults, zero budget: both nodes are already blown on
    // arrival ⇒ empty partial answers with zero comparisons, flagged on
    // the ticket and counted on the monitor lane.
    cluster.orchestrator.enable_admission(
        AdmissionConfig::new(dim, 4)
            .with_queue_cap(32)
            .with_budget_policy(BudgetPolicy::PartialResults),
    );
    for i in 0..3 {
        let got = cluster
            .orchestrator
            .submit(c.queries.point(i), Duration::ZERO)
            .unwrap()
            .wait()
            .unwrap();
        assert!(got.partial, "q={i}");
        assert_eq!(got.shed_nodes, 0, "PartialResults never sheds");
        assert!(got.neighbors.is_empty());
        assert_eq!(got.max_comparisons, 0, "no scan work on a spent budget");
        assert_eq!(got.per_node_comparisons, vec![vec![0u64; 2]; 2]);
        assert!(!got.prediction, "empty K-NN abstains to the majority class");
    }
    let st = cluster.orchestrator.admission().unwrap().stats();
    assert_eq!(st.monitor.partials, 3, "every zero-budget request must count as partial");
    assert_eq!(st.monitor.sheds, 0);

    // Shed, zero budget: both nodes reject before any scan work.
    cluster.orchestrator.enable_admission(
        AdmissionConfig::new(dim, 4).with_queue_cap(32).with_budget_policy(BudgetPolicy::Shed),
    );
    for i in 0..2 {
        let got = cluster
            .orchestrator
            .submit(c.queries.point(i), Duration::ZERO)
            .unwrap()
            .wait()
            .unwrap();
        assert!(got.partial, "q={i}");
        assert_eq!(got.shed_nodes, 2, "every node must shed an already-spent budget");
        assert!(got.neighbors.is_empty());
        assert_eq!(got.max_comparisons, 0);
    }
    let st = cluster.orchestrator.admission().unwrap().stats();
    assert_eq!(st.monitor.partials, 2);
    assert_eq!(st.monitor.sheds, 2);
}

#[test]
fn local_and_remote_nodes_enforce_the_same_shipped_budget() {
    // A MIXED cluster — node 0 in-process, node 1 behind a TCP loopback
    // server — must enforce identically: the cut ships ONE remaining
    // budget + policy, each node anchors it at its own arrival.
    let c = corpus(1600, 4, 66);
    let dim = c.data.dim;
    let p = lsh_params(&c.data, 30, 8, 9);
    let ranges = chunk_ranges(c.data.len(), 2);

    let shard0 = Arc::new(c.data.shard(ranges[0].clone()));
    let local = LocalNode::spawn(0, shard0, ranges[0].start as u64, &p, 2, native_engines(2));

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || serve_node(&listener, None).unwrap());
    let remote = RemoteNode::connect(
        addr,
        1,
        c.data.shard(ranges[1].clone()),
        ranges[1].start as u64,
        &p,
        2,
    )
    .unwrap();

    let nodes: Vec<Box<dyn NodeHandle>> = vec![Box::new(local), Box::new(remote)];
    let mut orch = Orchestrator::start(nodes, p.k, VoteConfig::default());
    let reference = build_cluster(&c.data, &p, &ClusterConfig::new(2, 2)).unwrap();

    // Shed @ spent budget: BOTH nodes (local and across the wire) shed.
    orch.enable_admission(
        AdmissionConfig::new(dim, 4).with_queue_cap(16).with_budget_policy(BudgetPolicy::Shed),
    );
    let r = orch.submit(c.queries.point(0), Duration::ZERO).unwrap().wait().unwrap();
    assert!(r.partial);
    assert_eq!(r.shed_nodes, 2, "local and remote must both shed the spent budget");
    assert!(r.neighbors.is_empty());
    assert_eq!(r.max_comparisons, 0);

    // PartialResults @ spent budget: both nodes return empty partials
    // with zero scan work — the flags cross the wire intact.
    orch.enable_admission(
        AdmissionConfig::new(dim, 4)
            .with_queue_cap(16)
            .with_budget_policy(BudgetPolicy::PartialResults),
    );
    let r = orch.submit(c.queries.point(1), Duration::ZERO).unwrap().wait().unwrap();
    assert!(r.partial);
    assert_eq!(r.shed_nodes, 0);
    assert_eq!(r.per_node_comparisons, vec![vec![0u64; 2]; 2]);

    // LogOnly with a real budget: the mixed cluster answers bit-identical
    // to an all-local reference cluster.
    orch.enable_admission(
        AdmissionConfig::new(dim, 4).with_queue_cap(16).with_budget_policy(BudgetPolicy::LogOnly),
    );
    let got = orch.submit(c.queries.point(2), Duration::from_millis(5)).unwrap().wait().unwrap();
    assert_bit_identical(&got, &reference.query(c.queries.point(2)).unwrap(), "mixed LogOnly");

    drop(orch);
    assert_eq!(server.join().unwrap(), 3, "remote node must account every budget frame");
}
