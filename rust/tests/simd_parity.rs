//! Cross-kernel parity: the SIMD scan kernels behind [`ScanKernel`]
//! dispatch must be bit-identical to the scalar reference at EVERY engine
//! entry point (single, range, batched, cancellable) and through every
//! index layer above them (SlshIndex, LiveIndex sealed + delta) — for
//! both metrics, with dims covering the fixed-dim specializations (30,
//! 32), every tail-remainder class (1, 3, 29, 31, 33, 37) and sub-quad
//! lengths.
//!
//! The default engine is runtime-dispatched, so `NativeEngine::new()`
//! running the whole existing parity battery already gates the detected
//! kernel; this suite adds the explicit scalar-vs-simd4 cross checks
//! (and, under `--features wide-simd`, tolerance checks for the 8-lane
//! AVX2 kernel, which is deliberately NOT bit-gated).

use dslsh::engine::native::NativeEngine;
use dslsh::engine::{l1_dist, DistanceEngine, Metric, ScanCancel, ScanKernel};
use dslsh::knn::TopK;
use dslsh::lsh::family::LayerSpec;
use dslsh::slsh::{
    BatchOutput, LiveIndex, LiveScratch, QueryScratch, SealPolicy, SlshIndex, SlshParams,
};
use dslsh::util::clock::MockClock;
use dslsh::util::rng::Xoshiro256;
use dslsh::util::stamp::StampSet;
use std::sync::Arc;

const DIMS: [usize; 8] = [1, 3, 29, 30, 31, 32, 33, 37];

fn fixture(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.2)).collect();
    let qs: Vec<f32> = (0..6 * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    (data, labels, qs)
}

fn scalar() -> NativeEngine {
    NativeEngine::with_kernel(ScanKernel::Scalar)
}

fn simd4() -> NativeEngine {
    NativeEngine::with_kernel(ScanKernel::Simd4)
}

/// The detected (default) kernel must itself be bit-identical to scalar —
/// the property that lets every pre-existing parity suite double as a
/// SIMD gate once dispatch is active.
#[test]
fn default_dispatch_is_bit_identical_to_scalar() {
    let auto = NativeEngine::new();
    assert_eq!(auto.kernel(), ScanKernel::detect());
    let reference = scalar();
    for dim in DIMS {
        let (data, labels, qs) = fixture(400, dim, 7);
        let ids: Vec<u32> = (0..400).filter(|i| i % 5 != 0).collect();
        for metric in [Metric::L1, Metric::Cosine] {
            let mut a = TopK::new(10);
            let mut b = TopK::new(10);
            reference.scan(metric, &qs[..dim], &data, dim, &ids, &labels, 9, &mut a);
            auto.scan(metric, &qs[..dim], &data, dim, &ids, &labels, 9, &mut b);
            assert_eq!(a.into_sorted(), b.into_sorted(), "dim={dim} metric={metric:?}");
        }
    }
}

/// scan / scan_range / scan_batch / scan_batch_range: pinned simd4 ==
/// pinned scalar, bit for bit, across the dim sweep and both metrics.
#[test]
fn every_entry_point_is_bit_identical_scalar_vs_simd4() {
    let (eng_s, eng_v) = (scalar(), simd4());
    for dim in DIMS {
        let (data, labels, qs) = fixture(500, dim, 11);
        let ids: Vec<u32> = (0..500).filter(|i| i % 3 != 0).collect();
        let nq = 6;
        for metric in [Metric::L1, Metric::Cosine] {
            // scan
            let mut a = TopK::new(8);
            let mut b = TopK::new(8);
            let ca = eng_s.scan(metric, &qs[..dim], &data, dim, &ids, &labels, 0, &mut a);
            let cb = eng_v.scan(metric, &qs[..dim], &data, dim, &ids, &labels, 0, &mut b);
            assert_eq!(ca, cb);
            assert_eq!(a.into_sorted(), b.into_sorted(), "scan dim={dim} metric={metric:?}");
            // scan_range
            let mut a = TopK::new(8);
            let mut b = TopK::new(8);
            eng_s.scan_range(metric, &qs[..dim], &data, dim, 23..471, &labels, 0, &mut a);
            eng_v.scan_range(metric, &qs[..dim], &data, dim, 23..471, &labels, 0, &mut b);
            assert_eq!(a.into_sorted(), b.into_sorted(), "range dim={dim} metric={metric:?}");
            // scan_batch
            let mut aa: Vec<TopK> = (0..nq).map(|_| TopK::new(8)).collect();
            let mut bb: Vec<TopK> = (0..nq).map(|_| TopK::new(8)).collect();
            eng_s.scan_batch(metric, &qs, &data, dim, &ids, &labels, 0, &mut aa);
            eng_v.scan_batch(metric, &qs, &data, dim, &ids, &labels, 0, &mut bb);
            for (qi, (x, y)) in aa.into_iter().zip(bb).enumerate() {
                assert_eq!(
                    x.into_sorted(),
                    y.into_sorted(),
                    "batch dim={dim} metric={metric:?} qi={qi}"
                );
            }
            // scan_batch_range
            let mut aa: Vec<TopK> = (0..nq).map(|_| TopK::new(8)).collect();
            let mut bb: Vec<TopK> = (0..nq).map(|_| TopK::new(8)).collect();
            eng_s.scan_batch_range(metric, &qs, &data, dim, 23..471, &labels, 0, &mut aa);
            eng_v.scan_batch_range(metric, &qs, &data, dim, 23..471, &labels, 0, &mut bb);
            for (qi, (x, y)) in aa.into_iter().zip(bb).enumerate() {
                assert_eq!(
                    x.into_sorted(),
                    y.into_sorted(),
                    "batch_range dim={dim} metric={metric:?} qi={qi}"
                );
            }
        }
    }
}

/// The cancellable entry points inherit dispatch through scan/scan_batch:
/// unbounded tokens give bit-identical full results; a mid-scan deadline
/// cuts both kernels at the same tile boundary with identical prefixes.
#[test]
fn cancellable_scans_are_bit_identical_scalar_vs_simd4() {
    let (eng_s, eng_v) = (scalar(), simd4());
    let dim = 30;
    let (data, labels, qs) = fixture(600, dim, 13);
    let ids: Vec<u32> = (0..600).collect();
    for metric in [Metric::L1, Metric::Cosine] {
        // Unbounded: identical to the plain scan on both engines.
        let mut a = TopK::new(10);
        let mut b = TopK::new(10);
        let ca = eng_s.scan_until(
            metric,
            &qs[..dim],
            &data,
            dim,
            &ids,
            &labels,
            0,
            &mut a,
            &ScanCancel::unbounded(Arc::new(MockClock::new(0))),
        );
        let cb = eng_v.scan_until(
            metric,
            &qs[..dim],
            &data,
            dim,
            &ids,
            &labels,
            0,
            &mut b,
            &ScanCancel::unbounded(Arc::new(MockClock::new(0))),
        );
        assert_eq!(ca, cb);
        assert_eq!(ca, ids.len() as u64);
        assert_eq!(a.into_sorted(), b.into_sorted(), "until metric={metric:?}");
        // Already-blown deadline: both engines do zero work.
        let mut a = TopK::new(10);
        let mut b = TopK::new(10);
        let blown_a = ScanCancel::until(Arc::new(MockClock::new(5)), 5);
        let blown_b = ScanCancel::until(Arc::new(MockClock::new(5)), 5);
        let ca = eng_s
            .scan_until(metric, &qs[..dim], &data, dim, &ids, &labels, 0, &mut a, &blown_a);
        let cb = eng_v
            .scan_until(metric, &qs[..dim], &data, dim, &ids, &labels, 0, &mut b, &blown_b);
        assert_eq!(ca, 0);
        assert_eq!(cb, 0);
        assert!(a.is_empty() && b.is_empty());
        // Batched cancellable range: unbounded twins are bit-identical
        // and report completion.
        let nq = 6;
        let mut aa: Vec<TopK> = (0..nq).map(|_| TopK::new(10)).collect();
        let mut bb: Vec<TopK> = (0..nq).map(|_| TopK::new(10)).collect();
        let pa = eng_s.scan_batch_range_until(
            metric,
            &qs,
            &data,
            dim,
            0..600,
            &labels,
            0,
            &mut aa,
            &ScanCancel::unbounded(Arc::new(MockClock::new(0))),
        );
        let pb = eng_v.scan_batch_range_until(
            metric,
            &qs,
            &data,
            dim,
            0..600,
            &labels,
            0,
            &mut bb,
            &ScanCancel::unbounded(Arc::new(MockClock::new(0))),
        );
        assert_eq!(pa, pb);
        assert!(pa.completed);
        for (qi, (x, y)) in aa.into_iter().zip(bb).enumerate() {
            assert_eq!(x.into_sorted(), y.into_sorted(), "until_batch metric={metric:?} qi={qi}");
        }
    }
}

/// Both engines against the naive sequential oracle: SIMD inherits the
/// scalar tail oracle because simd4 == scalar exactly, and scalar is
/// within reassociation tolerance of the reference.
#[test]
fn kernels_agree_with_naive_oracle_at_tail_dims() {
    let mut rng = Xoshiro256::seed_from_u64(17);
    for dim in DIMS {
        for _ in 0..100 {
            let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-80.0, 180.0) as f32).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-80.0, 180.0) as f32).collect();
            let data = b.clone();
            let labels = [false];
            let mut t_s = TopK::new(1);
            let mut t_v = TopK::new(1);
            scalar().scan(Metric::L1, &a, &data, dim, &[0], &labels, 0, &mut t_s);
            simd4().scan(Metric::L1, &a, &data, dim, &[0], &labels, 0, &mut t_v);
            let ds = t_s.into_sorted()[0].dist;
            let dv = t_v.into_sorted()[0].dist;
            assert_eq!(ds, dv, "dim={dim}");
            let reference = l1_dist(&a, &b);
            assert!(
                (ds - reference).abs() <= 1e-4 * (1.0 + reference.abs()),
                "dim={dim}: {ds} vs naive {reference}"
            );
        }
    }
}

/// Index-level parity: an SlshIndex (LSH-only AND stratified) queried
/// with the simd4 engine answers bit-identically — same neighbors, same
/// stats — to the scalar engine, on single and batched paths.
#[test]
fn slsh_index_parity_across_kernels() {
    let dim = 30;
    let mut rng = Xoshiro256::seed_from_u64(19);
    let n = 1500;
    let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let labels: Vec<bool> = (0..n).map(|i| i % 7 == 0).collect();
    let view = dslsh::lsh::layer::SliceView { data: &data, dim };
    let lsh_only = SlshParams::lsh_only(LayerSpec::outer_l1(dim, 24, 10, 20.0, 180.0, 5), 10);
    let stratified = SlshParams {
        outer: LayerSpec::outer_l1(dim, 12, 8, 20.0, 180.0, 5),
        inner: Some(dslsh::slsh::InnerParams { m: 24, l: 8, alpha: 0.05, seed: 0xBEEF }),
        k: 10,
    };
    let (eng_s, eng_v) = (scalar(), simd4());
    for params in [lsh_only, stratified] {
        let idx = SlshIndex::build_full(&params, &view);
        let mut scratch = QueryScratch::new(n);
        let (mut out_s, mut out_v) = (BatchOutput::new(), BatchOutput::new());
        let nq = 5;
        let qs: Vec<f32> = (0..nq * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
        idx.query_batch(&eng_s, &qs, &data, &labels, 0, &mut scratch, &mut out_s);
        idx.query_batch(&eng_v, &qs, &data, &labels, 0, &mut scratch, &mut out_v);
        for qi in 0..nq {
            assert_eq!(out_v.neighbors(qi), out_s.neighbors(qi), "qi={qi}");
            assert_eq!(out_v.stats(qi), out_s.stats(qi), "qi={qi}");
        }
        let mut visited = StampSet::new(n);
        let mut cand = Vec::new();
        for qi in 0..nq {
            let q = &qs[qi * dim..(qi + 1) * dim];
            let seq_s = idx.query(&eng_s, q, &data, &labels, 0, &mut visited, &mut cand);
            let seq_v = idx.query(&eng_v, q, &data, &labels, 0, &mut visited, &mut cand);
            assert_eq!(seq_v.topk.into_sorted(), seq_s.topk.into_sorted(), "qi={qi}");
            assert_eq!(seq_v.stats, seq_s.stats);
        }
    }
}

/// Live-index parity: a mixed segment stack (sealed segments + an active
/// delta) answers bit-identically under both kernels — the live-delta
/// scan call sites inherit dispatch too.
#[test]
fn live_index_parity_across_kernels() {
    let dim = 30;
    let mut rng = Xoshiro256::seed_from_u64(23);
    let n = 300;
    let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let labels: Vec<bool> = (0..n).map(|i| i % 6 == 0).collect();
    let params = SlshParams::lsh_only(LayerSpec::outer_l1(dim, 16, 8, 20.0, 180.0, 29), 10);
    let live = LiveIndex::new(&params, SealPolicy::by_size(90), Arc::new(MockClock::new(0)));
    live.insert_batch(&data, &labels);
    assert!(live.sealed_segments() > 0 && live.delta_len() > 0, "need a mixed stack");
    let (eng_s, eng_v) = (scalar(), simd4());
    let mut scratch = LiveScratch::new();
    let (mut out_s, mut out_v) = (BatchOutput::new(), BatchOutput::new());
    let nq = 4;
    let qs: Vec<f32> = (0..nq * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    live.query_batch(&eng_s, &qs, &mut scratch, &mut out_s);
    live.query_batch(&eng_v, &qs, &mut scratch, &mut out_v);
    for qi in 0..nq {
        assert_eq!(out_v.neighbors(qi), out_s.neighbors(qi), "qi={qi}");
        assert_eq!(out_v.stats(qi), out_s.stats(qi), "qi={qi}");
    }
    // Cancellable live path, unbounded: still identical across kernels.
    live.query_batch_cancel(
        &eng_s,
        &qs,
        &mut scratch,
        &mut out_s,
        &ScanCancel::unbounded(Arc::new(MockClock::new(0))),
    );
    live.query_batch_cancel(
        &eng_v,
        &qs,
        &mut scratch,
        &mut out_v,
        &ScanCancel::unbounded(Arc::new(MockClock::new(0))),
    );
    for qi in 0..nq {
        assert_eq!(out_v.neighbors(qi), out_s.neighbors(qi), "cancel qi={qi}");
        assert_eq!(out_v.stats(qi), out_s.stats(qi), "cancel qi={qi}");
    }
}

/// The wide kernel is tolerance-grade by contract: never auto-selected,
/// and its distances sit within relative 1e-5 of scalar. Top-K *ordering*
/// may legitimately differ on near-ties, so the comparison is by id →
/// distance map, not rank.
#[cfg(feature = "wide-simd")]
#[test]
fn simd8_engine_within_tolerance_of_scalar() {
    if !ScanKernel::simd8_available() {
        eprintln!("skipping simd8 engine test: AVX2 not detected on this host");
        return;
    }
    let eng_s = scalar();
    let eng_w = NativeEngine::with_kernel(ScanKernel::Simd8);
    for dim in [29usize, 30, 32, 37, 64] {
        let (data, labels, qs) = fixture(400, dim, 31);
        let ids: Vec<u32> = (0..400).collect();
        for metric in [Metric::L1, Metric::Cosine] {
            let k = 400; // full ranking, so both top-Ks hold every candidate
            let mut a = TopK::new(k);
            let mut b = TopK::new(k);
            eng_s.scan(metric, &qs[..dim], &data, dim, &ids, &labels, 0, &mut a);
            eng_w.scan(metric, &qs[..dim], &data, dim, &ids, &labels, 0, &mut b);
            let want: std::collections::HashMap<u64, f32> =
                a.into_sorted().iter().map(|n| (n.id, n.dist)).collect();
            for nb in b.into_sorted() {
                let ds = want[&nb.id];
                assert!(
                    (nb.dist - ds).abs() <= 1e-5 * (1.0 + ds.abs()),
                    "dim={dim} metric={metric:?} id={}: {} vs {}",
                    nb.id,
                    nb.dist,
                    ds
                );
            }
        }
    }
}
