//! Deterministic scheduling-semantics tests for the priority-class
//! admission lanes + pipelined dispatch.
//!
//! These are the two guarantees PR 2's FIFO/serial-dispatch cutter could
//! not give (its module docs documented the gap):
//!
//! 1. **Monitor budgets hold mid-dispatch.** A `Class::Monitor` request
//!    arriving while an analytics batch is on the cluster is CUT at its
//!    deadline, not up to one batch service time late. On the PR 2
//!    scheduler the cutter itself ran the dispatch, so the deadline
//!    check could not fire until the batch returned — the
//!    `monitor_cut_within_budget_while_analytics_batch_in_flight` test
//!    fails on that design and passes on the pipelined one.
//! 2. **Analytics cannot starve.** Under sustained monitor load, an
//!    analytics request is dispatched within the configured aging bound.
//!
//! Every test drives a [`MockClock`] and synchronizes through channel
//! handshakes plus bounded counter polls — the *outcomes* asserted are
//! deterministic; no assertion depends on real-time durations.

mod common;

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use common::{gated_echo, wait_until, FAR};
use dslsh::coordinator::admission::{AdmissionConfig, AdmissionQueue, Class, MockClock};

// `gated_echo` (the gated dispatcher every test here drives), `FAR`
// (budgets a frozen MockClock can never expire) and the bounded
// `wait_until` counter poll live in tests/common/mod.rs, shared with the
// parity and budget-enforcement suites. On the PR 2 scheduler the
// conditions these tests wait for can NEVER become true, so wait_until's
// bound doubles as the failure mode.

#[test]
fn monitor_cut_within_budget_while_analytics_batch_in_flight() {
    // The PR 2 overrun repro, now fixed. Timeline (mock ns):
    //   t=0     analytics {1.0, 2.0} fill-cut, dispatched, GATED — the
    //           batch is "on the cluster" and will stay there.
    //   t=0     monitor 9.0 submitted with a 1000ns budget.
    //   t=1000  the monitor's deadline: the cutter (no longer blocked
    //           inside the dispatch) must cut it NOW, while the
    //           analytics batch is still in flight.
    let clock = Arc::new(MockClock::new(0));
    let (evt_tx, evt_rx) = channel();
    let (gate_tx, gate_rx) = channel();
    let cfg = AdmissionConfig::new(1, 2).with_queue_cap(16).with_pipeline(2);
    let q = AdmissionQueue::start_with_clock(
        cfg,
        gated_echo(evt_tx, gate_rx),
        Arc::clone(&clock) as Arc<dyn dslsh::coordinator::Clock>,
    );

    let a1 = q.submit_class(&[1.0], FAR, Class::Analytics).unwrap();
    let a2 = q.submit_class(&[2.0], FAR, Class::Analytics).unwrap();
    assert_eq!(evt_rx.recv().unwrap(), vec![1.0, 2.0], "analytics batch must be in flight");

    let m = q.submit_class(&[9.0], Duration::from_nanos(1000), Class::Monitor).unwrap();
    clock.advance_ns(1000);

    // THE assertion: the monitor's deadline cut is recorded while the
    // analytics batch is still gated. On the PR 2 scheduler the cutter
    // is stuck inside the dispatch and this wait times out.
    let cuts = q.cut_counters();
    wait_until(
        || cuts.deadline() == 1,
        "monitor deadline cut while the analytics batch is in flight",
    );
    let st = q.stats();
    assert_eq!(st.depth, 0, "the monitor must have left the queue by its deadline");
    assert_eq!(st.monitor.dispatched_deadline, 1);
    assert_eq!(st.analytics.dispatched_fill, 2);

    // Let the in-flight analytics batch take 500ns longer: the monitor
    // batch then RESOLVES 500ns past its deadline — dispatched on time,
    // finished late — and the per-class overrun counters must say so.
    clock.advance_ns(500);
    gate_tx.send(()).unwrap(); // release the analytics batch
    assert_eq!(evt_rx.recv().unwrap(), vec![9.0], "monitor batch dispatches next");
    gate_tx.send(()).unwrap(); // release the monitor batch

    assert_eq!(m.wait().unwrap().positive_share, 9.0);
    assert_eq!(a1.wait().unwrap().positive_share, 1.0);
    assert_eq!(a2.wait().unwrap().positive_share, 2.0);
    let st = q.stats();
    assert_eq!(st.monitor.overruns, 1, "the late resolution must be attributed to the monitor");
    assert_eq!(st.analytics.overruns, 0, "FAR-budget analytics never overrun");
}

#[test]
fn analytics_dispatched_within_age_bound_under_sustained_monitor_load() {
    // Anti-starvation bound. The tricky part of testing it is building a
    // monitor backlog DETERMINISTICALLY: the cutter fill-cuts the moment
    // two requests are pending, so a backlog can only accumulate while
    // the cutter is parked handing a cut to the (gated) dispatcher. With
    // pipeline=1 the handoff is a rendezvous: once one batch is gated in
    // the dispatcher and a second is parked at the rendezvous, the
    // cutter is blocked and every submission just queues — no race
    // window between consecutive submits.
    let clock = Arc::new(MockClock::new(0));
    let (evt_tx, evt_rx) = channel();
    let (gate_tx, gate_rx) = channel();
    let cfg = AdmissionConfig::new(1, 2)
        .with_queue_cap(16)
        .with_pipeline(1)
        .with_age_bound(Duration::from_nanos(1000));
    let q = AdmissionQueue::start_with_clock(
        cfg,
        gated_echo(evt_tx, gate_rx),
        Arc::clone(&clock) as Arc<dyn dslsh::coordinator::Clock>,
    );

    // Plug the pipeline: {x1,x2} gated in the dispatcher, {y1,y2} parked
    // at the rendezvous — from here on the cutter cannot cut.
    let x1 = q.submit_class(&[8.0], FAR, Class::Monitor).unwrap();
    let x2 = q.submit_class(&[9.0], FAR, Class::Monitor).unwrap();
    assert_eq!(evt_rx.recv().unwrap(), vec![8.0, 9.0]);
    let y1 = q.submit_class(&[6.0], FAR, Class::Monitor).unwrap();
    let y2 = q.submit_class(&[7.0], FAR, Class::Monitor).unwrap();
    wait_until(|| q.stats().completed == 4, "second batch parked at the rendezvous");

    // Sustained load: analytics request A, then a queue of monitors
    // behind it — under pure strict priority A would wait out every one
    // of them.
    let a = q.submit_class(&[0.5], FAR, Class::Analytics).unwrap();
    let m1 = q.submit_class(&[1.0], FAR, Class::Monitor).unwrap();
    let m2 = q.submit_class(&[2.0], FAR, Class::Monitor).unwrap();
    let m3 = q.submit_class(&[3.0], FAR, Class::Monitor).unwrap();
    assert_eq!(q.stats().analytics.depth, 1, "A is waiting behind the plug");

    // A's age crosses the bound while the backlog is still queued: the
    // very next cut the cutter forms must give A a slot ahead of the
    // monitors.
    clock.advance_ns(1000);
    gate_tx.send(()).unwrap(); // release {x1,x2}; cutter unblocks and cuts
    assert_eq!(evt_rx.recv().unwrap(), vec![6.0, 7.0]);
    gate_tx.send(()).unwrap(); // release {y1,y2}
    assert_eq!(
        evt_rx.recv().unwrap(),
        vec![0.5, 1.0],
        "aged A takes a slot of the first post-bound cut, ahead of the monitor backlog"
    );
    gate_tx.send(()).unwrap(); // release {A,m1}
    assert_eq!(evt_rx.recv().unwrap(), vec![2.0, 3.0]);
    gate_tx.send(()).unwrap(); // release {m2,m3}

    assert_eq!(a.wait().unwrap().positive_share, 0.5);
    for (t, want) in
        [(x1, 8.0), (x2, 9.0), (y1, 6.0), (y2, 7.0), (m1, 1.0), (m2, 2.0), (m3, 3.0)]
    {
        assert_eq!(t.wait().unwrap().positive_share, want);
    }
    let st = q.stats();
    assert_eq!(st.cuts_fill, 4, "every cut here was a fill cut");
    assert_eq!(st.analytics.dispatched_fill, 1, "A rode a fill cut via the aging bound");
    assert_eq!(st.monitor.dispatched_fill, 7);
    assert_eq!(st.depth, 0);
    assert_eq!(st.monitor.overruns + st.analytics.overruns, 0, "far deadlines never overrun");
}

#[test]
fn pipelined_dispatch_forms_next_cut_while_batch_in_flight() {
    // Direct witness of the overlap: with one batch gated on the
    // cluster, the cutter still forms (and buffers) the next cut — the
    // completed counter advances while the first dispatch has not
    // returned. On the serial PR 2 dispatcher, completed would stay at
    // the first batch's size until the gate opened.
    let (evt_tx, evt_rx) = channel();
    let (gate_tx, gate_rx) = channel();
    let cfg = AdmissionConfig::new(1, 2).with_queue_cap(16).with_pipeline(2);
    let q = AdmissionQueue::start_with_clock(
        cfg,
        gated_echo(evt_tx, gate_rx),
        Arc::new(MockClock::new(0)),
    );

    let t1 = q.submit(&[1.0], FAR).unwrap();
    let t2 = q.submit(&[2.0], FAR).unwrap();
    assert_eq!(evt_rx.recv().unwrap(), vec![1.0, 2.0]);
    let t3 = q.submit(&[3.0], FAR).unwrap();
    let t4 = q.submit(&[4.0], FAR).unwrap();
    wait_until(
        || q.stats().completed == 4,
        "cut N+1 to form while cut N is in flight",
    );
    gate_tx.send(()).unwrap();
    assert_eq!(evt_rx.recv().unwrap(), vec![3.0, 4.0]);
    gate_tx.send(()).unwrap();
    for (t, want) in [(t1, 1.0), (t2, 2.0), (t3, 3.0), (t4, 4.0)] {
        assert_eq!(t.wait().unwrap().positive_share, want);
    }
}
