//! The observability subsystem under test: exact per-stage span durations
//! on a frozen `MockClock` (no sleeps, no tolerances — span arithmetic is
//! pinned to the nanosecond), bit-identity of traced vs untraced results
//! over a real TCP cluster, slow-ring cause attribution (slow / shed /
//! partial / hedged priority), the always-on per-shard histograms, the
//! Prometheus scrape surface (`GET /metrics` must expose EVERY stats
//! family), the slow-query debug endpoint, and the per-cause counters for
//! requests the cluster would otherwise drop silently (TCP decode
//! rejects, HTTP 4xxs).

mod common;

use std::net::TcpListener;
use std::sync::mpsc::channel;
use std::sync::Arc;

use common::*;
use dslsh::coordinator::admission::{AdmissionConfig, AdmissionQueue, Class};
use dslsh::coordinator::{Clock, MockClock, ReplicaSet};
use dslsh::net::{serve_node, EdgeConfig, EdgeServer};
use dslsh::runtime::service::decode_reject_counts;
use dslsh::runtime::trace::{Span, Tracer};

// ---------------------------------------------------------------------------
// Exact span durations through admission (MockClock, zero tolerance)
// ---------------------------------------------------------------------------

/// Two queries through a traced admission queue on a frozen `MockClock`,
/// the in-flight batch gated by the test: every span boundary is a clock
/// value the test set explicitly, so queue-wait, service and e2e are
/// asserted EXACTLY — to the nanosecond on spans, to the microsecond on
/// histograms. The choreography is race-free because the clock only
/// moves while the dispatcher is provably parked at the gate.
#[test]
fn admission_spans_are_exact_under_mock_clock() {
    let clock = Arc::new(MockClock::new(0));
    let tracer = Arc::new(Tracer::new(Arc::clone(&clock) as Arc<dyn Clock>, 1));
    tracer.set_collect(true);
    tracer.set_slow_threshold_us(1); // Everything lands in the ring.

    let (evt_tx, evt_rx) = channel();
    let (gate_tx, gate_rx) = channel();
    let cfg = AdmissionConfig::new(1, 1).with_pipeline(1);
    let q = AdmissionQueue::start_traced(cfg, gated_echo(evt_tx, gate_rx), Arc::clone(&tracer));

    // A enqueues at t=0 and its cut starts dispatch at t=0 (the clock
    // does not move until the dispatcher has reported the batch).
    let ta = q.submit(&[0.5], FAR).unwrap();
    assert_eq!(evt_rx.recv().unwrap(), vec![0.5]);

    // B enqueues at t=7µs while A is in flight; its cut can only start
    // once A resolves.
    clock.set_ns(7_000);
    let tb = q.submit(&[0.25], FAR).unwrap();

    // A resolves at t=10µs: queue-wait 0, service 10µs, e2e 10µs. B's
    // dispatch then starts at the same instant (the clock next moves
    // only after B's batch is reported): queue-wait exactly 3µs.
    clock.set_ns(10_000);
    gate_tx.send(()).unwrap();
    let ra = ta.wait().unwrap();
    assert!(ra.positive_share == 0.5);
    assert_eq!(evt_rx.recv().unwrap(), vec![0.25]);

    // B resolves at t=25µs: service 15µs, e2e 18µs.
    clock.set_ns(25_000);
    gate_tx.send(()).unwrap();
    let rb = tb.wait().unwrap();
    assert!(rb.positive_share == 0.25);

    // Lane histograms: exact sums and counts, in microseconds.
    let h = tracer.lane_hists(Class::Monitor.idx());
    assert_eq!((h.e2e_us.count, h.e2e_us.sum), (2, 28), "e2e 10 + 18");
    assert_eq!((h.queue_wait_us.count, h.queue_wait_us.sum), (2, 3), "waits 0 + 3");
    assert_eq!((h.service_us.count, h.service_us.sum), (2, 25), "service 10 + 15");

    // The slow ring holds both traces, oldest first, with exact spans.
    let ring = tracer.slow_ring();
    assert_eq!(ring.len(), 2, "{ring:?}");
    let a = &ring[0];
    assert_eq!((a.trace_id, a.cause, a.e2e_us), (1, "slow", 10));
    assert_eq!(
        a.spans,
        vec![
            Span { stage: "queue_wait", start_ns: 0, dur_ns: 0 },
            Span { stage: "service", start_ns: 0, dur_ns: 10_000 },
        ]
    );
    let b = &ring[1];
    assert_eq!((b.trace_id, b.cause, b.e2e_us), (2, "slow", 18));
    assert_eq!(
        b.spans,
        vec![
            Span { stage: "queue_wait", start_ns: 7_000, dur_ns: 3_000 },
            Span { stage: "service", start_ns: 10_000, dur_ns: 15_000 },
        ]
    );
}

// ---------------------------------------------------------------------------
// Traced == untraced over a real TCP cluster; shard histograms always on
// ---------------------------------------------------------------------------

/// Turning span collection on changes the wire frames (the trace id
/// forces the budget frame) but must not change a single result bit.
/// Shard-level scan/network histograms populate either way — they are
/// the always-on tier and never depend on `set_collect`.
#[test]
fn traced_results_are_bit_identical_over_tcp() {
    let c = corpus(160, 4, 23);
    let params = lsh_params(&c.data, 8, 4, 5);
    let baseline = reference_orchestrator(&c.data, &params, 2, 1);
    let (orch, servers) = tcp_cluster(&c.data, &params, 2, 1);

    // Phase 1: collection OFF (the default). Park the slow threshold at
    // the ceiling so wall-clock hiccups cannot seed the ring.
    let tracer = orch.tracer();
    tracer.set_slow_threshold_us(u64::MAX);
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        let want = baseline.query(q).unwrap();
        let got = orch.query(q).unwrap();
        assert_bit_identical(&got, &want, &format!("untraced query {i}"));
    }
    assert!(tracer.slow_ring().is_empty(), "nothing slow, shed or partial yet");

    // Phase 2: collection ON, threshold 0 — every query is ring-worthy,
    // and every result is still bit-identical to the baseline.
    tracer.set_collect(true);
    tracer.set_slow_threshold_us(0);
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        let want = baseline.query(q).unwrap();
        let got = orch.query(q).unwrap();
        assert_bit_identical(&got, &want, &format!("traced query {i}"));
    }

    // Every traced query produced a full trace: one NodeSpan per shard,
    // tables from the actual scan, nonzero dense trace ids.
    let ring = tracer.slow_ring();
    assert_eq!(ring.len(), c.queries.len(), "{ring:?}");
    for t in &ring {
        assert!(t.trace_id > 0);
        assert_eq!(t.cause, "slow");
        assert!(t.spans.iter().any(|s| s.stage == "service"), "{t:?}");
        let mut shards: Vec<usize> = t.nodes.iter().map(|n| n.shard).collect();
        shards.sort_unstable();
        assert_eq!(shards, vec![0, 1], "one node span per shard: {t:?}");
        for n in &t.nodes {
            assert!(n.tables >= 1, "scan covered at least one table: {n:?}");
            assert!(!n.shed, "healthy cluster sheds nothing");
        }
    }

    // Always-on tier: both phases recorded into the shard histograms —
    // single-replica shards cannot hedge or fail over, so exactly one
    // record per query per shard per phase.
    let per_shard = 2 * c.queries.len() as u64;
    for shard in 0..tracer.num_shards() {
        let h = tracer.shard_hists(shard);
        assert_eq!(h.scan_us.count, per_shard, "shard {shard} scan records");
        assert_eq!(h.net_us.count, per_shard, "shard {shard} net records");
    }

    drop(orch);
    for s in servers {
        s.join().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Cause attribution: shed through a dead shard, hedged via the tracer API
// ---------------------------------------------------------------------------

/// A query against a cluster whose second shard is dead lands in the
/// slow ring attributed to "shed" (the synthesized shed reply), with the
/// healthy shard's node span attached and the dead shard's absent.
#[test]
fn dead_shard_traces_are_attributed_to_shed() {
    let c = corpus(96, 1, 31);
    let params = lsh_params(&c.data, 8, 4, 5);
    let parts = shard_parts(&c.data, 2);
    let clock = Arc::new(MockClock::new(0));
    let switch = FaultSwitch::new();
    switch.set(|p| p.fail_requests = true);

    let sets = vec![
        ReplicaSet::new(0, vec![boxed(spawn_replica(&parts[0].1, 0, parts[0].0, &params, 1))]),
        ReplicaSet::new(
            1,
            vec![boxed(FaultyNode::new(
                spawn_replica(&parts[1].1, 1, parts[1].0, &params, 1),
                Arc::clone(&switch),
            ))],
        ),
    ];
    let orch = replicated_orch(sets, params.k, quiet_failover(), &clock);
    let tracer = orch.tracer();
    tracer.set_collect(true);

    let r = orch.query(c.queries.point(0)).unwrap();
    assert!(r.shed_nodes >= 1, "dead shard must be shed: {r:?}");

    // Frozen clock → e2e is 0µs, far under the slow threshold: the ring
    // entry is there because of the shed, and says so.
    let ring = tracer.slow_ring();
    assert_eq!(ring.len(), 1, "{ring:?}");
    let t = &ring[0];
    assert_eq!((t.cause, t.shed, t.e2e_us), ("shed", true, 0));
    assert_eq!(t.nodes.len(), 1, "only the healthy shard replied: {t:?}");
    assert_eq!(t.nodes[0].shard, 0);
    assert!(!t.nodes[0].shed);
}

/// `finish` ranks causes slow > shed > partial > hedged, and an
/// unremarkable fast query never enters the ring at all.
#[test]
fn finish_ranks_causes_and_drops_clean_queries() {
    let clock = Arc::new(MockClock::new(0));
    let tracer = Tracer::new(clock as Arc<dyn Clock>, 1);
    tracer.set_collect(true);

    // Clean and fast: no ring entry.
    let id = tracer.mint(0);
    tracer.finish(id, 0, 5, false, false);
    assert!(tracer.slow_ring().is_empty());

    // Hedged only.
    let id = tracer.mint(0);
    tracer.note_hedge(id);
    tracer.finish(id, 0, 5, false, false);
    // Partial beats hedged.
    let id = tracer.mint(1);
    tracer.note_hedge(id);
    tracer.finish(id, 1, 5, true, false);
    // Shed beats partial.
    let id = tracer.mint(0);
    tracer.finish(id, 0, 5, true, true);
    // Slow beats everything.
    tracer.set_slow_threshold_us(1);
    let id = tracer.mint(0);
    tracer.finish(id, 0, 5, true, true);

    let ring = tracer.slow_ring();
    let causes: Vec<&str> = ring.iter().map(|t| t.cause).collect();
    assert_eq!(causes, vec!["hedged", "partial", "shed", "slow"]);
    assert!(ring[0].hedged && !ring[0].partial && !ring[0].shed);
}

// ---------------------------------------------------------------------------
// The scrape surface: /metrics, /v1/debug/slow, /v1/stats percentiles
// ---------------------------------------------------------------------------

/// Value of the first exposition line starting with `prefix`.
fn metric_value(body: &str, prefix: &str) -> u64 {
    let line = body
        .lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("no metric line starts with {prefix:?}"));
    let v = line.rsplit(' ').next().unwrap();
    v.parse::<f64>().unwrap_or_else(|e| panic!("bad value in {line:?}: {e}")) as u64
}

/// One scrape of `GET /metrics` exposes every family the cluster keeps:
/// edge, admission queue, cuts, lanes, ingest, failover, the tracer's
/// per-lane and per-shard histograms, and both dropped-input counters —
/// with non-empty histogram buckets after a served workload. The stats
/// document grows percentiles, and `/v1/debug/slow` dumps the ring.
#[test]
fn metrics_scrape_exposes_every_family() {
    let c = corpus(160, 4, 37);
    let params = lsh_params(&c.data, 8, 4, 5);
    let mut orch = reference_orchestrator(&c.data, &params, 2, 1);
    orch.enable_admission(AdmissionConfig::new(c.data.dim, 1));
    let orch = Arc::new(orch);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let edge = EdgeServer::start(Arc::clone(&orch), listener, EdgeConfig::new(c.data.dim)).unwrap();
    let a = edge.addr();

    // Ring-worthy traffic: collect spans and call everything slow.
    let tracer = orch.tracer();
    tracer.set_collect(true);
    tracer.set_slow_threshold_us(0);

    let query_body = |q: &[f32]| {
        let coords: Vec<String> = q.iter().map(|v| format!("{v}")).collect();
        format!("{{\"point\":[{}]}}", coords.join(","))
    };
    for i in 0..c.queries.len() {
        let r = http_post(a, "/v1/query", &query_body(c.queries.point(i)));
        assert_eq!(r.status, 200, "query {i}: {}", r.body);
    }
    // One hostile request the edge rejects — it must be COUNTED, not
    // silently dropped: a POST the edge cannot frame (no Content-Length).
    let r = http_send_raw(a, b"POST /v1/query HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(r.status, 411);
    // The edge records its counters after the response is on the wire;
    // wait for them (the outcome is deterministic, the instant is not).
    wait_until(|| edge.stats().query.requests == c.queries.len() as u64, "edge query counters");

    // The stats document now carries distribution summaries per endpoint.
    let s = http_get(a, "/v1/stats");
    assert_eq!(s.status, 200);
    let eq = s.json().get("edge").unwrap().get("query").unwrap().clone();
    assert_eq!(eq.get("requests").unwrap().as_u64(), Some(c.queries.len() as u64));
    for key in ["latency_us_mean", "latency_us_p50", "latency_us_p99"] {
        assert!(eq.get(key).is_some(), "stats edge.query missing {key}: {}", s.body);
    }

    // The scrape itself.
    let m = http_get(a, "/metrics");
    assert_eq!(m.status, 200);
    assert_eq!(m.header("content-type"), Some("text/plain; version=0.0.4"));
    let body = &m.body;
    for family in [
        "dslsh_edge_requests_total",
        "dslsh_edge_errors_total",
        "dslsh_edge_latency_us",
        "dslsh_admission_depth",
        "dslsh_admission_high_water",
        "dslsh_admission_submitted_total",
        "dslsh_admission_completed_total",
        "dslsh_admission_rejected_full_total",
        "dslsh_admission_cuts_total",
        "dslsh_lane_depth",
        "dslsh_lane_submitted_total",
        "dslsh_lane_dispatched_total",
        "dslsh_lane_overruns_total",
        "dslsh_lane_partials_total",
        "dslsh_lane_sheds_total",
        "dslsh_lane_inserted_total",
        "dslsh_lane_rejected_full_total",
        "dslsh_lane_probes",
        "dslsh_lane_ewma_comparisons",
        "dslsh_ingest_batches_total",
        "dslsh_ingest_points_total",
        "dslsh_ingest_sealed_segments",
        "dslsh_failover_hedges_total",
        "dslsh_failover_hedge_wins_total",
        "dslsh_failover_failovers_total",
        "dslsh_failover_synthesized_sheds_total",
        "dslsh_failover_heartbeats_total",
        "dslsh_failover_reconnect_attempts_total",
        "dslsh_failover_reconnects_total",
        "dslsh_failover_down_transitions_total",
        "dslsh_replicas_down",
        "dslsh_lane_queue_wait_us",
        "dslsh_lane_service_us",
        "dslsh_lane_e2e_us",
        "dslsh_shard_net_us",
        "dslsh_shard_scan_us",
        "dslsh_tcp_decode_rejects_total",
        "dslsh_http_rejects_total",
    ] {
        assert!(body.contains(&format!("# TYPE {family} ")), "missing family {family}");
    }

    // Non-empty buckets where the workload guarantees them.
    let nq = c.queries.len() as u64;
    assert_eq!(metric_value(body, "dslsh_edge_requests_total{endpoint=\"query\"}"), nq);
    assert_eq!(metric_value(body, "dslsh_lane_e2e_us_count{lane=\"monitor\"}"), nq);
    assert!(body.contains("dslsh_lane_e2e_us_bucket{lane=\"monitor\",le=\"+Inf\"}"));
    assert_eq!(metric_value(body, "dslsh_shard_scan_us_count{shard=\"0\"}"), nq);
    assert_eq!(metric_value(body, "dslsh_shard_scan_us_count{shard=\"1\"}"), nq);
    assert!(
        metric_value(body, "dslsh_http_rejects_total{code=\"length-required\"}") >= 1,
        "the rejected POST must be counted"
    );

    // The slow ring over HTTP: every served query is in it.
    let slow = http_get(a, "/v1/debug/slow");
    assert_eq!(slow.status, 200);
    let entries = slow.json().get("slow").unwrap().as_arr().unwrap().len();
    assert_eq!(entries, c.queries.len(), "{}", slow.body);

    // Wrong method on the scrape surfaces is a 405, and the scrape
    // endpoint's own traffic shows up in the next scrape.
    assert_eq!(http_post(a, "/metrics", "{}").status, 405);
    wait_until(|| edge.stats().metrics.requests >= 3, "metrics endpoint counters");
    let m2 = http_get(a, "/metrics");
    assert!(metric_value(&m2.body, "dslsh_edge_requests_total{endpoint=\"metrics\"}") >= 3);
}

// ---------------------------------------------------------------------------
// Silently-dropped inputs are counted: TCP decode rejects
// ---------------------------------------------------------------------------

/// Garbage on a node port tears the connection down (that contract is
/// tcp.rs's), but the drop is attributed: the ASCII length prefix decodes
/// to ~1.7 GB, past `MAX_FRAME`, so the process-wide decode-reject counter
/// gains a `too_long` entry the scrape can render.
#[test]
fn tcp_decode_rejects_are_counted_by_kind() {
    let before: u64 = decode_reject_counts()
        .iter()
        .filter(|(k, _)| *k == "too_long")
        .map(|&(_, v)| v)
        .sum();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // serve_node propagates the decode failure as Err — expected here.
    let server = std::thread::spawn(move || serve_node(&listener, None).is_err());

    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(b"definitely not a dslsh frame").unwrap();
        let _ = s.shutdown(std::net::Shutdown::Write);
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    assert!(server.join().unwrap(), "garbage build frame must error out");

    let after: u64 = decode_reject_counts()
        .iter()
        .filter(|(k, _)| *k == "too_long")
        .map(|&(_, v)| v)
        .sum();
    assert!(after > before, "decode reject must be counted ({before} -> {after})");
}
