//! End-to-end: full three-layer stack (synthetic data -> SLSH cluster ->
//! XLA/PJRT hot path -> prediction) and its parity with the native path.
//! Requires `make artifacts`.

use dslsh::coordinator::{build_cluster, ClusterConfig, EngineKind};
use dslsh::data::{build_corpus, CorpusConfig, WindowSpec};
use dslsh::experiments::{eval_cluster, eval_pknn, outer_params};
use dslsh::knn::predict::VoteConfig;

#[test]
#[cfg_attr(
    not(feature = "xla"),
    ignore = "requires --features xla (PJRT runtime is stubbed offline) and `make artifacts`"
)]
fn xla_cluster_matches_native_cluster_end_to_end() {
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), 6000, 40, 55));
    let params = outer_params(&corpus.data, 72, 16, 3, 10);
    let native = build_cluster(&corpus.data, &params, &ClusterConfig::new(2, 2)).unwrap();
    let xla = match build_cluster(
        &corpus.data,
        &params,
        &ClusterConfig::new(2, 2).with_engine(EngineKind::Xla),
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping: XLA runtime unavailable ({e:#})");
            return;
        }
    };
    for i in 0..corpus.queries.len() {
        let q = corpus.queries.point(i);
        let a = native.query(q).unwrap();
        let b = xla.query(q).unwrap();
        assert_eq!(a.prediction, b.prediction, "query {i}");
        assert_eq!(a.max_comparisons, b.max_comparisons, "query {i}");
        assert_eq!(
            a.neighbors.iter().map(|n| n.id).collect::<Vec<_>>(),
            b.neighbors.iter().map(|n| n.id).collect::<Vec<_>>(),
            "query {i}"
        );
    }
}

#[test]
fn full_pipeline_beats_pknn_with_bounded_mcc_loss() {
    // The paper's core claim at miniature scale: an order of magnitude
    // fewer comparisons with bounded prediction-quality loss.
    let corpus = build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), 24_000, 800, 77));
    let params = outer_params(&corpus.data, 150, 48, 11, 10);
    let cluster = build_cluster(&corpus.data, &params, &ClusterConfig::new(2, 4)).unwrap();
    let run = eval_cluster(&cluster, &corpus);
    let pknn = eval_pknn(&corpus.data, &corpus.queries, 10, 8, &VoteConfig::default());
    let speedup = pknn.comps_per_proc as f64 / run.median_comps.max(1.0);
    assert!(speedup > 2.0, "speedup {speedup:.2} too low");
    // PKNN itself must be predictive on this corpus...
    assert!(pknn.mcc > 0.15, "baseline MCC {:.3} — corpus not learnable", pknn.mcc);
    // ...and DSLSH must stay within a generous quality budget.
    let loss = pknn.mcc - run.mcc;
    assert!(loss < 0.5, "MCC loss {loss:.3} too high (pknn {:.3}, dslsh {:.3})", pknn.mcc, run.mcc);
}
