//! Deterministic fault-tolerance suite: replica death, hedged requests,
//! synthesized sheds, reconnect backoff, replicated-insert ack
//! accounting and the TCP fault → failover → reconnect cycle.
//!
//! No sleeps anywhere. Every timer the dispatcher owns (hedge delay,
//! request timeout, heartbeat cadence, reconnect backoff) reads the
//! injected `MockClock`, so each test pins timing by advancing the clock
//! and `wait_until` only bounds the scheduler's *delivery* of an outcome
//! that is already determined. The baseline for every assertion is an
//! UNREPLICATED orchestrator over the same shard layout: replication and
//! failover must change availability, never answers — degraded paths are
//! asserted field by field (`shed_nodes`, `partial`) against it.

// The positional submit/query entry points are deprecated shims over the
// QuerySpec API; this file exercises them on purpose (they must keep
// working bit-identically until removal).
#![allow(deprecated)]

mod common;

use std::net::TcpListener;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use common::*;
use dslsh::coordinator::{
    AdmissionConfig, ClusterError, FailoverConfig, MockClock, Orchestrator, ReplicaSet,
    SystemClock,
};
use dslsh::knn::predict::VoteConfig;
use dslsh::net::wire::Message;
use dslsh::net::{serve_node_loop, RemoteNode};
use dslsh::node::node::LocalNode;
use dslsh::slsh::SealPolicy;

/// Two shards, two replicas each, every replica healthy: replication
/// must be invisible — single and batch answers bit-identical to the
/// unreplicated baseline, zero failover activity, and (after the clock
/// crosses the heartbeat period) heartbeats that probe every replica
/// without perturbing anything.
#[test]
fn healthy_replicas_are_bit_identical_to_unreplicated() {
    let c = corpus(2000, 20, 11);
    let params = lsh_params(&c.data, 40, 12, 5);
    let reference = reference_orchestrator(&c.data, &params, 2, 2);

    let clock = Arc::new(MockClock::new(0));
    let cfg = FailoverConfig { heartbeat_every: Duration::from_secs(1), ..quiet_failover() };
    let sets = replica_sets(&shard_parts(&c.data, 2), |shard, base, slice| {
        (0..2).map(|_| boxed(spawn_replica(slice, shard, base, &params, 2))).collect()
    });
    let orch = replicated_orch(sets, params.k, cfg, &clock);

    for i in 0..10 {
        let got = orch.query(c.queries.point(i)).unwrap();
        let want = reference.query(c.queries.point(i)).unwrap();
        assert_bit_identical(&got, &want, &format!("query {i}"));
    }
    let qs: Vec<&[f32]> = (10..20).map(|i| c.queries.point(i)).collect();
    let got = orch.query_batch(&qs).unwrap();
    let want = reference.query_batch(&qs).unwrap();
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_bit_identical(g, w, &format!("batch query {i}"));
    }

    let stats = orch.failover_stats();
    assert_eq!(stats.hedges, 0, "frozen clock: the hedge delay can never elapse");
    assert_eq!(stats.failovers, 0);
    assert_eq!(stats.synthesized_sheds, 0);
    assert_eq!(stats.down_transitions, 0);
    assert_eq!(stats.heartbeats, 0, "heartbeat cadence is clock-driven; the clock is frozen");

    // Cross the heartbeat period: all four replicas get probed (batch
    // nodes answer "alive, not live-indexed"), and answers afterwards
    // are still bit-identical — the detector's traffic is invisible to
    // the workload.
    clock.advance(Duration::from_secs(1));
    wait_until(|| orch.failover_stats().heartbeats >= 4, "all four replicas heartbeated");
    let got = orch.query(c.queries.point(0)).unwrap();
    let want = reference.query(c.queries.point(0)).unwrap();
    assert_bit_identical(&got, &want, "post-heartbeat query");
    assert_eq!(orch.failover_stats().down_transitions, 0);
}

/// Kill a shard's preferred replica mid-run: the detecting query pays
/// one failover hop to the twin and still returns the FULL answer
/// (`shed_nodes == 0`); once the replica is `Down` it is routed around,
/// so exactly one failover per kill is recorded. Covers both the single
/// and batch dispatch paths.
#[test]
fn killed_replica_fails_over_without_shedding() {
    let c = corpus(2000, 20, 11);
    let params = lsh_params(&c.data, 40, 12, 5);
    let reference = reference_orchestrator(&c.data, &params, 2, 2);

    let clock = Arc::new(MockClock::new(0));
    let mut switches = Vec::new();
    let sets = replica_sets(&shard_parts(&c.data, 2), |shard, base, slice| {
        let switch = FaultSwitch::new();
        let inner = spawn_replica(slice, shard, base, &params, 2);
        let primary = FaultyNode::new(inner, Arc::clone(&switch));
        switches.push(switch);
        let twin = spawn_replica(slice, shard, base, &params, 2);
        vec![boxed(primary), boxed(twin)]
    });
    let orch = replicated_orch(sets, params.k, quiet_failover(), &clock);

    // Healthy warm-up through the (still well-behaved) primaries.
    for i in 0..5 {
        let got = orch.query(c.queries.point(i)).unwrap();
        let want = reference.query(c.queries.point(i)).unwrap();
        assert_bit_identical(&got, &want, &format!("warm-up query {i}"));
    }
    assert_eq!(orch.failover_stats().failovers, 0);

    // Kill shard 0's primary. The next query that touches it fails over
    // to the twin; the caller never sees a shed or an error.
    switches[0].set(|p| p.fail_requests = true);
    for i in 5..15 {
        let got = orch.query(c.queries.point(i)).unwrap();
        let want = reference.query(c.queries.point(i)).unwrap();
        assert_bit_identical(&got, &want, &format!("query {i} across the kill"));
    }
    let stats = orch.failover_stats();
    assert_eq!(stats.down_transitions, 1);
    assert_eq!(stats.failovers, 1, "only the detecting query pays the hop; Down is routed around");
    assert_eq!(stats.synthesized_sheds, 0);
    assert_eq!(stats.reconnect_attempts, 0, "frozen clock: backoff cannot elapse");

    // Kill shard 1's primary too and take the batch path across it.
    switches[1].set(|p| p.fail_requests = true);
    let qs: Vec<&[f32]> = (0..8).map(|i| c.queries.point(i)).collect();
    let got = orch.query_batch(&qs).unwrap();
    let want = reference.query_batch(&qs).unwrap();
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_bit_identical(g, w, &format!("batch query {i} across the second kill"));
    }
    let stats = orch.failover_stats();
    assert_eq!(stats.down_transitions, 2);
    assert_eq!(stats.failovers, 2);
    assert_eq!(stats.synthesized_sheds, 0);
}

/// A shard whose ONLY replica is dead cannot answer — but the cluster
/// must degrade, not hang or error: the dispatcher synthesizes a shed
/// reply immediately (errors are prompt, not timeouts), the caller gets
/// the live shards' answer with `shed_nodes == 1` and `partial` set, and
/// the admission path completes monitor tickets the same way.
#[test]
fn dead_shard_degrades_to_synthesized_shed_not_a_hang() {
    let c = corpus(2000, 12, 11);
    let params = lsh_params(&c.data, 40, 12, 5);
    let parts = shard_parts(&c.data, 2);

    // Baseline: shard 0 alone — the dead shard must contribute nothing.
    let solo = vec![boxed(spawn_replica(&parts[0].1, 0, parts[0].0, &params, 2))];
    let reference = Orchestrator::start(solo, params.k, VoteConfig::default());

    let clock = Arc::new(MockClock::new(0));
    let switch = FaultSwitch::new();
    switch.set(|p| p.fail_requests = true); // dead before the first request
    let healthy = boxed(spawn_replica(&parts[0].1, 0, parts[0].0, &params, 2));
    let inner = spawn_replica(&parts[1].1, 1, parts[1].0, &params, 2);
    let dead = FaultyNode::new(inner, Arc::clone(&switch));
    let sets = vec![ReplicaSet::new(0, vec![healthy]), ReplicaSet::new(1, vec![boxed(dead)])];
    let mut orch = replicated_orch(sets, params.k, quiet_failover(), &clock);

    for i in 0..2 {
        let got = orch.query(c.queries.point(i)).unwrap();
        let want = reference.query(c.queries.point(i)).unwrap();
        assert_eq!(got.neighbors, want.neighbors, "query {i}: only shard 0 contributes");
        assert_eq!(got.prediction, want.prediction, "query {i}");
        assert_eq!(got.max_comparisons, want.max_comparisons, "query {i}");
        assert!(got.partial, "query {i}: a shed shard makes the answer partial");
        assert_eq!(got.shed_nodes, 1, "query {i}");
        let zeros = vec![0u64; 2];
        assert_eq!(got.per_node_comparisons[1], zeros, "query {i}: dead shard scanned nothing");
    }
    let stats = orch.failover_stats();
    assert_eq!(stats.down_transitions, 1, "first failure marks Down; later queries skip it");
    assert_eq!(stats.synthesized_sheds, 2);

    // Batch path: one synthesized shed covers the whole lost job, and
    // every rider degrades identically.
    let qs: Vec<&[f32]> = (2..5).map(|i| c.queries.point(i)).collect();
    for (i, g) in orch.query_batch(&qs).unwrap().iter().enumerate() {
        assert_eq!(g.shed_nodes, 1, "batch query {i}");
        assert!(g.partial, "batch query {i}");
    }
    assert_eq!(orch.failover_stats().synthesized_sheds, 3);

    // Monitor tickets through the admission layer complete promptly too:
    // the shed is synthesized on failure, not at request_timeout (which
    // is parked FAR away and would time the test out if waited on).
    // max_batch = 1 so the lone submit triggers an immediate fill cut.
    orch.enable_admission(AdmissionConfig::new(c.data.dim, 1).with_queue_cap(16));
    let ticket = orch.submit(c.queries.point(5), FAR).unwrap();
    let r = ticket.wait().unwrap();
    assert_eq!(r.shed_nodes, 1);
    assert!(r.partial);
}

/// Hedge timing, pinned: with the primary stalling (not dead) and the
/// clock frozen 1 ms short of `hedge_after`, no hedge may fire and the
/// query cannot complete; crossing the threshold fires exactly one hedge
/// to the twin, whose reply wins and is bit-identical to the baseline.
#[test]
fn hedge_fires_exactly_at_the_configured_delay() {
    let c = corpus(1500, 8, 3);
    let params = lsh_params(&c.data, 40, 12, 5);
    let parts = shard_parts(&c.data, 1);
    let reference = reference_orchestrator(&c.data, &params, 1, 2);

    let clock = Arc::new(MockClock::new(0));
    let switch = FaultSwitch::new();
    switch.set(|p| p.block_queries = true); // a straggler, not a corpse
    let inner = spawn_replica(&parts[0].1, 0, parts[0].0, &params, 2);
    let straggler = FaultyNode::new(inner, Arc::clone(&switch));
    let twin = spawn_replica(&parts[0].1, 0, parts[0].0, &params, 2);
    let sets = vec![ReplicaSet::new(0, vec![boxed(straggler), boxed(twin)])];
    let cfg = FailoverConfig { hedge_after: Duration::from_millis(100), ..quiet_failover() };
    let orch = replicated_orch(sets, params.k, cfg, &clock);

    let (tx, rx) = channel();
    std::thread::scope(|s| {
        s.spawn(|| {
            tx.send(orch.query(c.queries.point(0)).unwrap()).unwrap();
        });
        // The primary is holding the query. One millisecond short of the
        // hedge delay nothing may happen: the twin has not been asked,
        // so completing is impossible — not merely unlikely.
        wait_until(|| switch.requests_seen() >= 1, "the primary to receive the query");
        clock.advance(Duration::from_millis(99));
        assert_eq!(orch.failover_stats().hedges, 0, "hedge before hedge_after");
        assert!(rx.try_recv().is_err(), "query completed with its only live replica stalled");

        // Crossing hedge_after fires the hedge; the twin answers and the
        // straggler never influences the result.
        clock.advance(Duration::from_millis(1));
        wait_until(|| orch.failover_stats().hedges == 1, "the hedge to fire");
        let got = rx.recv().unwrap();
        let want = reference.query(c.queries.point(0)).unwrap();
        assert_bit_identical(&got, &want, "hedged query");
        let stats = orch.failover_stats();
        assert_eq!(stats.hedge_wins, 1, "the twin's reply won the race");
        assert_eq!(stats.failovers, 0);
        assert_eq!(stats.synthesized_sheds, 0);
        assert_eq!(stats.down_transitions, 0, "a straggler is Suspect, never Down");

        // Release the straggler so its runner can drain; the late reply
        // is absorbed, never completing the query twice.
        switch.set(|p| p.block_queries = false);
    });
}

/// Reconnect backoff is gated by the injected clock, exactly: the first
/// attempt is due `reconnect_base` (10 ms) after the death, fires at
/// 10 ms and not at 10 ms − 1 ns, and a revived node rejoins through a
/// successful attempt — after which queries are full and bit-identical
/// again.
#[test]
fn reconnect_backoff_is_gated_by_the_injected_clock() {
    let c = corpus(1500, 8, 3);
    let params = lsh_params(&c.data, 40, 12, 5);
    let parts = shard_parts(&c.data, 1);
    let reference = reference_orchestrator(&c.data, &params, 1, 2);

    let clock = Arc::new(MockClock::new(0));
    let switch = FaultSwitch::new();
    switch.set(|p| {
        p.fail_requests = true;
        p.fail_reconnects = true;
    });
    let inner = spawn_replica(&parts[0].1, 0, parts[0].0, &params, 2);
    let faulty = FaultyNode::new(inner, Arc::clone(&switch));
    let sets = vec![ReplicaSet::new(0, vec![boxed(faulty)])];
    let orch = replicated_orch(sets, params.k, quiet_failover(), &clock);

    // The first query detects the death at t = 0 and schedules the first
    // reconnect attempt for t = 10 ms; both queries degrade to sheds.
    for i in 0..2 {
        let r = orch.query(c.queries.point(i)).unwrap();
        assert_eq!(r.shed_nodes, 1, "query {i}");
        assert!(r.partial, "query {i}");
    }
    assert_eq!(orch.failover_stats().down_transitions, 1);
    assert_eq!(orch.failover_stats().reconnect_attempts, 0, "frozen clock: nothing is due");

    // 1 ns short of due: serving another query drives the dispatcher
    // through its duty cycle, yet the attempt must not fire.
    clock.set_ns(10_000_000 - 1);
    let r = orch.query(c.queries.point(2)).unwrap();
    assert_eq!(r.shed_nodes, 1);
    assert_eq!(orch.failover_stats().reconnect_attempts, 0, "attempt fired before its due time");

    // At exactly 10 ms the attempt fires — and fails, re-arming the
    // schedule at the next exponential step.
    clock.set_ns(10_000_000);
    wait_until(|| switch.reconnects_seen() == 1, "the first attempt to reach the node");
    assert_eq!(orch.failover_stats().reconnect_attempts, 1);
    assert_eq!(orch.failover_stats().reconnects, 0);

    // Revive the node and walk the clock forward: the next due attempt
    // succeeds, the replica rejoins (as Suspect), and the very next
    // query is complete and bit-identical again.
    switch.set(|p| {
        p.fail_requests = false;
        p.fail_reconnects = false;
    });
    wait_until(
        || {
            clock.advance(Duration::from_millis(5));
            orch.failover_stats().reconnects == 1
        },
        "the reconnect to succeed",
    );
    let got = orch.query(c.queries.point(3)).unwrap();
    let want = reference.query(c.queries.point(3)).unwrap();
    assert_bit_identical(&got, &want, "post-recovery query");
    assert_eq!(orch.failover_stats().synthesized_sheds, 3, "only pre-recovery queries shed");
}

/// The full TCP cycle: a remote replica's connection dies mid-request →
/// the dispatcher fails over to the in-process sibling (full answer) →
/// the backoff re-dials through `serve_node_loop`, which replays the
/// build bit-identically → when the sibling later dies, traffic fails
/// over BACK onto the fresh connection. The honest server's query count
/// proves the reconnected link carried the post-recovery traffic.
#[test]
fn tcp_fault_fails_over_then_reconnects_on_a_fresh_connection() {
    let c = corpus(1500, 8, 3);
    let params = lsh_params(&c.data, 40, 12, 5);
    let parts = shard_parts(&c.data, 1);
    let reference = reference_orchestrator(&c.data, &params, 1, 2);

    let listener = Arc::new(TcpListener::bind("127.0.0.1:0").unwrap());
    let addr = listener.local_addr().unwrap();

    // Flaky first connection: serve the build honestly, then read exactly
    // one request and vanish without replying — a mid-request disconnect
    // the client must surface as a fault, not a panic or a hang.
    let flaky = {
        let listener = Arc::clone(&listener);
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
            let mut writer = std::io::BufWriter::new(stream);
            let build = Message::read_frame(&mut reader).unwrap().unwrap();
            let Message::Build { shard, .. } = build else {
                panic!("expected Build, got {build:?}");
            };
            Message::BuildDone { node_id: 0, shard_len: shard.len() as u64, build_ms: 0.0 }
                .write_frame(&mut writer)
                .unwrap();
            let _ = Message::read_frame(&mut reader).unwrap();
        })
    };

    let remote =
        RemoteNode::connect(addr, 0, c.data.shard(0..c.data.len()), 0, &params, 2).unwrap();
    let switch = FaultSwitch::new();
    let inner = spawn_replica(&parts[0].1, 0, parts[0].0, &params, 2);
    let sibling = FaultyNode::new(inner, Arc::clone(&switch));
    let clock = Arc::new(MockClock::new(0));
    let sets = vec![ReplicaSet::new(0, vec![boxed(remote), boxed(sibling)])];
    let orch = replicated_orch(sets, params.k, quiet_failover(), &clock);

    // Query 0 hits the remote primary, whose connection dies mid-request;
    // the dispatcher fails over to the sibling. Full answer, no shed.
    let got = orch.query(c.queries.point(0)).unwrap();
    let want = reference.query(c.queries.point(0)).unwrap();
    assert_bit_identical(&got, &want, "query across the TCP fault");
    flaky.join().unwrap();
    let stats = orch.failover_stats();
    assert_eq!(stats.down_transitions, 1);
    assert_eq!(stats.failovers, 1);

    // Honest server for the recovery: re-accepts once, gets the replayed
    // build frame, serves until the cluster shuts down.
    let server = {
        let listener = Arc::clone(&listener);
        std::thread::spawn(move || serve_node_loop(&listener, None, 1).unwrap())
    };
    clock.advance(Duration::from_millis(20)); // past the 10 ms first backoff
    wait_until(|| orch.failover_stats().reconnects == 1, "the TCP reconnect");

    // Kill the sibling: traffic must fail over BACK to the revived
    // remote, over the fresh connection and the bit-identically rebuilt
    // index.
    switch.set(|p| p.fail_requests = true);
    for i in 1..3 {
        let got = orch.query(c.queries.point(i)).unwrap();
        let want = reference.query(c.queries.point(i)).unwrap();
        assert_bit_identical(&got, &want, &format!("query {i} on the reconnected remote"));
    }
    let stats = orch.failover_stats();
    assert_eq!(stats.down_transitions, 2);
    assert_eq!(stats.failovers, 2);
    assert_eq!(stats.synthesized_sheds, 0);

    // Clean shutdown closes the remote; the honest server saw exactly
    // the two post-reconnect queries (heartbeats are parked FAR away and
    // never count toward the served total anyway).
    drop(orch);
    assert_eq!(server.join().unwrap(), 2);
}

/// Replicated ingest: a batch fans out to every live replica and the ack
/// reports exactly how many hold it; one dead replica degrades the ack
/// count (the data stays durable and queryable), zero live replicas is a
/// loud [`ClusterError::ShardUnavailable`] — never silent data loss.
#[test]
fn replicated_insert_fans_out_and_reports_ack_count() {
    let c = corpus(1500, 8, 3);
    let d = &c.data;
    let params = lsh_params(d, 40, 12, 5);
    let policy = SealPolicy::by_size(500);

    let clock = Arc::new(MockClock::new(0));
    let switches = [FaultSwitch::new(), FaultSwitch::new()];
    let replicas: Vec<_> = switches
        .iter()
        .map(|sw| {
            // Replicas mint ids from the same base and apply the same
            // batches in the same order, so they stay interchangeable.
            let inner = LocalNode::spawn_live(
                0,
                0,
                &params,
                2,
                native_engines(2),
                Arc::new(SystemClock::new()),
                policy,
            );
            boxed(FaultyNode::new(inner, Arc::clone(sw)))
        })
        .collect();
    let sets = vec![ReplicaSet::new(0, replicas)];
    let orch = replicated_orch(sets, params.k, quiet_failover(), &clock);

    // Healthy: the batch lands on every replica.
    let dim = d.dim;
    let out = orch.insert_batch(&d.points[..250 * dim], &d.labels[..250]).unwrap();
    assert_eq!(out.replicas_acked, 2, "healthy fan-out reaches both replicas");
    assert_eq!(out.accepted, 250);
    assert_eq!(out.node_total, 250);

    // One replica dead: the batch is still durable (one ack) and the
    // caller is told exactly how many replicas hold it.
    switches[0].set(|p| p.fail_requests = true);
    let out = orch.insert_batch(&d.points[250 * dim..500 * dim], &d.labels[250..500]).unwrap();
    assert_eq!(out.replicas_acked, 1, "a dead replica cannot ack");
    assert_eq!(out.node_total, 500);
    assert_eq!(orch.failover_stats().down_transitions, 1);

    // The surviving replica serves queries over BOTH batches.
    let r = orch.query(d.point(300)).unwrap();
    assert!(
        r.neighbors.iter().any(|n| n.id == 300 && n.dist == 0.0),
        "a point from the degraded batch must be indexed: {:?}",
        r.neighbors
    );
    assert_eq!(r.shed_nodes, 0);

    // Zero acks is an error, not silent data loss...
    switches[1].set(|p| p.fail_requests = true);
    let err = orch.insert_batch(&d.points[500 * dim..501 * dim], &d.labels[500..501]).unwrap_err();
    assert_eq!(err, ClusterError::ShardUnavailable { shard: 0 });
    // ...while queries degrade to a shed instead of hanging.
    let r = orch.query(d.point(0)).unwrap();
    assert_eq!(r.shed_nodes, 1);
    assert!(r.partial);
    assert!(r.neighbors.is_empty());
}

/// The PR 6 known gap, closed: a *live* (streaming) remote replica that
/// dies and reconnects used to come back EMPTY — the retained
/// `BuildLive` frame replays the node's configuration, not its data.
/// The shard dispatcher now keeps the acked insert history and replays
/// it through the fresh connection before promoting the replica, so the
/// reconnected node holds the SAME points (and global ids) it held
/// before the crash, and the detector only reports it healthy once the
/// replay caught up.
#[test]
fn reconnected_live_replica_is_repopulated_by_replay() {
    let c = corpus(200, 2, 27);
    let d = &c.data;
    let params = lsh_params(d, 8, 4, 5);
    let policy = SealPolicy::by_size(500);

    let listener = Arc::new(TcpListener::bind("127.0.0.1:0").unwrap());
    let addr = listener.local_addr().unwrap();

    // Flaky first connection: the live build and one insert batch are
    // served honestly, then the peer vanishes on the next request.
    let flaky = {
        let listener = Arc::clone(&listener);
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
            let mut writer = std::io::BufWriter::new(stream);
            let build = Message::read_frame(&mut reader).unwrap().unwrap();
            assert!(matches!(build, Message::BuildLive { .. }), "expected BuildLive: {build:?}");
            Message::BuildDone { node_id: 0, shard_len: 0, build_ms: 0.0 }
                .write_frame(&mut writer)
                .unwrap();
            let insert = Message::read_frame(&mut reader).unwrap().unwrap();
            let Message::InsertBatch { seq, n, .. } = insert else {
                panic!("expected InsertBatch, got {insert:?}");
            };
            Message::InsertAck { seq, accepted: n, total: n, sealed_now: 0, sealed_total: 0 }
                .write_frame(&mut writer)
                .unwrap();
            let _ = Message::read_frame(&mut reader);
        })
    };

    let remote = RemoteNode::connect_live(addr, 0, 0, &params, 2, policy).unwrap();
    let clock = Arc::new(MockClock::new(0));
    let sets = vec![ReplicaSet::new(0, vec![boxed(remote)])];
    let orch = replicated_orch(sets, params.k, quiet_failover(), &clock);

    // Ingest lands on the sole replica and is acknowledged.
    let out = orch.insert_batch(&d.points[..200 * d.dim], &d.labels[..200]).unwrap();
    assert_eq!(out.replicas_acked, 1);
    assert_eq!(out.accepted, 200);

    // The replica dies mid-query: synthesized shed, marked Down, and the
    // readiness gauge counts it.
    let r = orch.query(c.queries.point(0)).unwrap();
    assert_eq!(r.shed_nodes, 1);
    assert!(r.partial);
    flaky.join().unwrap();
    let stats = orch.failover_stats();
    assert_eq!(stats.down_transitions, 1);
    assert_eq!(stats.replicas_down, 1, "the readiness gauge sees the dead replica");

    // Honest recovery: the backoff re-dials, the retained BuildLive
    // replays the configuration, and the dispatcher replays the acked
    // insert history before declaring the replica healthy again. The
    // reconnect counter only advances once the replay succeeded, so
    // waiting on it pins the full recovery.
    let server = {
        let listener = Arc::clone(&listener);
        std::thread::spawn(move || serve_node_loop(&listener, None, 1).unwrap())
    };
    clock.advance(Duration::from_millis(20)); // past the 10 ms first backoff
    wait_until(|| orch.failover_stats().reconnects == 1, "the live reconnect + replay");
    assert_eq!(orch.failover_stats().replicas_down, 0, "the gauge recovered");

    // THE GAP, CLOSED: the reconnected live node was re-fed the 200
    // acked points, so it answers with its pre-crash data — a point it
    // ingested comes back at distance 0 under its original global id.
    let r = orch.query(c.queries.point(1)).unwrap();
    assert_eq!(r.shed_nodes, 0);
    assert!(!r.partial);
    assert!(!r.neighbors.is_empty(), "the replayed shard must answer with data");
    let r = orch.query(d.point(5)).unwrap();
    assert!(
        r.neighbors.iter().any(|n| n.id == 5 && n.dist == 0.0),
        "replayed point 5 must be indexed under its original id: {:?}",
        r.neighbors
    );

    drop(orch);
    // Two post-recovery queries crossed the fresh connection; the replay
    // traffic is inserts and does not count toward the served total.
    assert_eq!(server.join().unwrap(), 2, "the revived server carried the post-recovery queries");
}
