//! The HTTP/JSON serving edge under fire: a hostile-input battery over
//! the raw HTTP framing, the JSON schema layer, and the parser property
//! corpora (truncation-at-every-byte + seeded mutation, shared with the
//! binary wire codec), plus the deterministic end-to-end contract — an
//! HTTP round trip through admission is bit-identical to a direct
//! `Orchestrator::submit_class`, backpressure surfaces as `429` with
//! `Retry-After`, blown budgets as `206`-flagged partials, and `/readyz`
//! tracks the failure detector's replica gauge. Every timing-sensitive
//! assertion runs on an injected `MockClock` — no sleeps anywhere.

// The positional submit/query entry points are deprecated shims over the
// QuerySpec API; this file exercises them on purpose (they must keep
// working bit-identically until removal).
#![allow(deprecated)]

mod common;

use std::io::{Cursor, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::*;
use dslsh::coordinator::admission::{AdmissionConfig, Budget, BudgetPolicy, Class};
use dslsh::coordinator::{Clock, MockClock, Orchestrator, QueryResult, ReplicaSet, SystemClock};
use dslsh::data::{Corpus, Dataset};
use dslsh::knn::Neighbor;
use dslsh::net::http::parse_request;
use dslsh::net::{EdgeConfig, EdgeServer, Limits, Message};
use dslsh::node::node::LocalNode;
use dslsh::slsh::{SealPolicy, LIVE_ID_STRIDE};
use dslsh::util::json::{Json, JsonObj};

// ---------------------------------------------------------------------------
// Fixtures and JSON plumbing
// ---------------------------------------------------------------------------

/// A small admission-free cluster behind an edge — the fixture for the
/// hostile-input battery and the direct-path (no admission) tests.
fn direct_edge() -> (Arc<Orchestrator>, EdgeServer, Corpus) {
    let c = corpus(96, 4, 11);
    let params = lsh_params(&c.data, 8, 4, 5);
    let orch = Arc::new(reference_orchestrator(&c.data, &params, 2, 1));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let cfg = EdgeConfig::new(c.data.dim);
    let edge = EdgeServer::start(Arc::clone(&orch), listener, cfg).unwrap();
    (orch, edge, c)
}

fn point_json(q: &[f32]) -> Json {
    Json::Arr(q.iter().map(|&v| Json::Num(f64::from(v))).collect())
}

/// `{"point": [...]}` — the minimal valid query body.
fn query_body(q: &[f32]) -> String {
    let mut o = JsonObj::new();
    o.insert("point", point_json(q));
    Json::Obj(o).to_string_compact()
}

/// `{"points": [[...]..], "labels": [..]}` over `data[at..at+take]`.
fn insert_body(data: &Dataset, at: usize, take: usize) -> String {
    let mut o = JsonObj::new();
    o.insert("points", Json::Arr((at..at + take).map(|i| point_json(data.point(i))).collect()));
    o.insert(
        "labels",
        Json::Arr(data.labels[at..at + take].iter().map(|&b| Json::Bool(b)).collect()),
    );
    Json::Obj(o).to_string_compact()
}

/// Reconstruct a [`QueryResult`] from the edge's response body. `dist`
/// values were widened f32 → f64 exactly and the writer prints
/// shortest-roundtrip floats, so this recovers bit-identical values.
fn result_from_json(j: &Json) -> QueryResult {
    let field = |name: &str| j.get(name).unwrap_or_else(|| panic!("missing field {name}: {j:?}"));
    QueryResult {
        qid: field("qid").as_u64().unwrap(),
        neighbors: field("neighbors")
            .as_arr()
            .unwrap()
            .iter()
            .map(|n| Neighbor {
                id: n.get("id").unwrap().as_u64().unwrap(),
                dist: n.get("dist").unwrap().as_f64().unwrap() as f32,
                label: n.get("label").unwrap().as_bool().unwrap(),
            })
            .collect(),
        positive_share: field("positive_share").as_f64().unwrap(),
        prediction: field("prediction").as_bool().unwrap(),
        max_comparisons: field("max_comparisons").as_u64().unwrap(),
        per_node_comparisons: field("per_node_comparisons")
            .as_arr()
            .unwrap()
            .iter()
            .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_u64().unwrap()).collect())
            .collect(),
        latency_s: field("latency_s").as_f64().unwrap(),
        partial: field("partial").as_bool().unwrap(),
        shed_nodes: field("shed_nodes").as_u64().unwrap() as u32,
    }
}

// ---------------------------------------------------------------------------
// Hostile HTTP framing
// ---------------------------------------------------------------------------

/// Malformed framing never panics, never hangs, and always yields the
/// specific typed 4xx/5xx the module contract promises.
#[test]
fn hostile_framing_is_rejected_with_typed_errors() {
    let (_orch, edge, _c) = direct_edge();
    let a = edge.addr();

    let big_head = format!("GET /healthz HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(20_000));
    let cases: Vec<(Vec<u8>, u16, &str)> = vec![
        // POST without Content-Length: the edge cannot frame the body.
        (b"POST /v1/query HTTP/1.1\r\nHost: t\r\n\r\n".to_vec(), 411, "length-required"),
        // Two Content-Length headers: request-smuggling ambiguity.
        (
            b"POST /v1/query HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\n{}"
                .to_vec(),
            400,
            "duplicate-content-length",
        ),
        // CR/LF injection inside a header value.
        (
            b"GET /healthz HTTP/1.1\r\nX-A: a\rX-Injected: 1\r\n\r\n".to_vec(),
            400,
            "bare-cr",
        ),
        // LF-only line endings.
        (b"GET /healthz HTTP/1.1\nHost: t\r\n\r\n".to_vec(), 400, "bare-lf"),
        // Chunked bodies are not accepted (no smuggling surface).
        (
            b"POST /v1/query HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n".to_vec(),
            400,
            "transfer-encoding-unsupported",
        ),
        // Declared body over the 1 MiB cap: rejected before any read.
        (
            format!("POST /v1/insert HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 2 << 20)
                .into_bytes(),
            413,
            "body-too-large",
        ),
        // Non-numeric Content-Length.
        (
            b"POST /v1/query HTTP/1.1\r\nContent-Length: ten\r\n\r\n".to_vec(),
            400,
            "bad-content-length",
        ),
        // Head over the 16 KiB cap.
        (big_head.into_bytes(), 431, "head-too-large"),
        // Client dies mid-body.
        (
            b"POST /v1/query HTTP/1.1\r\nContent-Length: 10\r\n\r\n{\"po".to_vec(),
            400,
            "truncated-body",
        ),
        // More bytes than declared.
        (
            b"POST /v1/query HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}trailing".to_vec(),
            400,
            "excess-body",
        ),
        // Unsupported protocol version.
        (b"GET /healthz HTTP/2.0\r\n\r\n".to_vec(), 505, "bad-version"),
        // Folded (obsolete) header continuations.
        (
            b"GET /healthz HTTP/1.1\r\nX-A: 1\r\n  folded\r\n\r\n".to_vec(),
            400,
            "obs-fold",
        ),
        // Garbage request line.
        (b"not http at all\r\n\r\n".to_vec(), 400, "bad-request-line"),
        // Invalid UTF-8 where the JSON body should be.
        (
            b"POST /v1/query HTTP/1.1\r\nContent-Length: 4\r\n\r\n\xff\xfe{}".to_vec(),
            400,
            "body-not-utf8",
        ),
    ];
    for (bytes, status, code) in &cases {
        let r = http_send_raw(a, bytes);
        assert_eq!(
            (r.status, r.error_code().as_str()),
            (*status, *code),
            "case {:?} → {}",
            String::from_utf8_lossy(&bytes[..bytes.len().min(60)]),
            r.body
        );
    }
}

/// Wrong methods answer `405` with an `Allow` header attributed to the
/// endpoint's counters; unknown paths are a `404`.
#[test]
fn wrong_method_and_unknown_path_are_typed() {
    let (_orch, edge, _c) = direct_edge();
    let a = edge.addr();

    let r = http_get(a, "/v1/query");
    assert_eq!((r.status, r.error_code().as_str()), (405, "method-not-allowed"));
    assert_eq!(r.header("Allow"), Some("POST"));

    let r = http_post(a, "/healthz", "{}");
    assert_eq!(r.status, 405);
    assert_eq!(r.header("Allow"), Some("GET"));

    let r = http_get(a, "/v1/nope");
    assert_eq!((r.status, r.error_code().as_str()), (404, "not-found"));

    wait_until(
        || {
            let s = edge.stats();
            s.query.requests == 1 && s.health.requests == 1 && s.other.requests == 1
        },
        "edge counters to attribute the rejects",
    );
    let s = edge.stats();
    assert_eq!((s.query.errors, s.health.errors, s.other.errors), (1, 1, 1));
}

// ---------------------------------------------------------------------------
// Hostile JSON schemas
// ---------------------------------------------------------------------------

/// Structurally valid HTTP with hostile JSON: every case is the specific
/// typed 400 from the schema layer, and none of them reaches the cluster.
#[test]
fn hostile_json_bodies_are_typed_400s() {
    let (_orch, edge, c) = direct_edge();
    let a = edge.addr();
    let pt = point_json(c.queries.point(0)).to_string_compact();

    let deep = format!("{}1{}", "[".repeat(4096), "]".repeat(4096));
    let query_cases: Vec<(String, &str)> = vec![
        // Not JSON at all.
        ("point=1,2,3".into(), "bad-json"),
        // Parser hardening: nesting past the depth cap...
        (deep, "bad-json"),
        // ...duplicate keys...
        (format!("{{\"point\":{pt},\"point\":{pt}}}"), "bad-json"),
        // ...and non-finite / overflowing numbers.
        ("{\"point\":[1e99999]}".into(), "bad-json"),
        // Top level must be an object.
        (format!("[{pt}]"), "schema"),
        // Required field missing.
        ("{}".into(), "missing-field"),
        // Fields outside the schema are rejected, not ignored.
        (format!("{{\"point\":{pt},\"evil\":1}}"), "unknown-field"),
        // A point must be an array of numbers of the cluster's dim.
        ("{\"point\":3}".into(), "bad-point"),
        ("{\"point\":[1,2,3]}".into(), "bad-dimension"),
        // Right dimension, wrong component type.
        (
            {
                let mut comps = vec![Json::Bool(true)];
                comps.extend((1..c.data.dim).map(|_| Json::Num(1.0)));
                let mut o = JsonObj::new();
                o.insert("point", Json::Arr(comps));
                Json::Obj(o).to_string_compact()
            },
            "bad-point",
        ),
        // Enum and integer fields validate strictly.
        (format!("{{\"point\":{pt},\"class\":\"vip\"}}"), "bad-class"),
        (format!("{{\"point\":{pt},\"budget_us\":-5}}"), "bad-budget"),
        (format!("{{\"point\":{pt},\"budget_us\":1.5}}"), "bad-budget"),
        (format!("{{\"point\":{pt},\"policy\":\"fast\"}}"), "bad-policy"),
    ];
    for (body, code) in &query_cases {
        let r = http_post(a, "/v1/query", body);
        assert_eq!(r.status, 400, "body {body:?} → {}", r.body);
        assert_eq!(r.error_code(), *code, "body {body:?}");
    }

    let insert_cases: Vec<(String, &str)> = vec![
        ("{\"points\":5,\"labels\":[]}".into(), "bad-points"),
        (format!("{{\"points\":[{pt}]}}"), "bad-labels"),
        ("{\"points\":[],\"labels\":[]}".into(), "empty-batch"),
        (format!("{{\"points\":[{pt}],\"labels\":[true,false]}}"), "length-mismatch"),
        (format!("{{\"points\":[{pt}],\"labels\":[1]}}"), "bad-labels"),
        (format!("{{\"points\":[[1,2]],\"labels\":[true]}}"), "bad-dimension"),
    ];
    for (body, code) in &insert_cases {
        let r = http_post(a, "/v1/insert", body);
        assert_eq!(r.status, 400, "body {body:?} → {}", r.body);
        assert_eq!(r.error_code(), *code, "body {body:?}");
    }
}

// ---------------------------------------------------------------------------
// Slowloris: the read deadline runs on the injected clock
// ---------------------------------------------------------------------------

/// A client that sends half a request and stalls is cut off with a `408`
/// when the *injected* clock passes the read deadline — the test drives
/// the MockClock; no real timeout is waited out.
#[test]
fn stalled_request_times_out_on_the_injected_clock() {
    let c = corpus(96, 2, 3);
    let params = lsh_params(&c.data, 8, 4, 5);
    let orch = Arc::new(reference_orchestrator(&c.data, &params, 1, 1));
    let clock = Arc::new(MockClock::new(0));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let cfg = EdgeConfig::new(c.data.dim).with_read_timeout(Duration::from_millis(50));
    let edge = EdgeServer::start_with_clock(
        Arc::clone(&orch),
        listener,
        cfg,
        Arc::clone(&clock) as Arc<dyn Clock>,
    )
    .unwrap();

    let mut s = TcpStream::connect(edge.addr()).unwrap();
    s.write_all(b"GET /healthz HTTP/1.1\r\nX-Slow: tric").unwrap();
    // Advance the clock in steps larger than the deadline until the
    // server's next poll observes it expired; the handler computes its
    // deadline from the clock value at accept, so stepping (rather than
    // one big jump racing the accept) is what makes this deterministic.
    s.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
    let mut buf = Vec::new();
    let t0 = Instant::now();
    loop {
        clock.advance(Duration::from_millis(60));
        let mut chunk = [0u8; 1024];
        match s.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => {}
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "no 408 before the real-time bound");
    }
    let r = parse_http_response(&buf);
    assert_eq!((r.status, r.error_code().as_str()), (408, "timeout"));
    drop(s);
    wait_until(|| edge.stats().other.errors == 1, "the timeout to be counted");
}

// ---------------------------------------------------------------------------
// Property corpora: one hostile-input discipline, two codecs
// ---------------------------------------------------------------------------

fn canonical_request() -> Vec<u8> {
    let body = r#"{"point":[1,2,3],"budget_us":1000}"#;
    format!(
        "POST /v1/query HTTP/1.1\r\nHost: a\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Truncation at every byte: a cut-off request is always a typed error,
/// never a partial success, never a panic or a hang.
#[test]
fn http_parser_rejects_every_truncation() {
    let full = canonical_request();
    let clock = MockClock::new(0);
    let limits = Limits::default();
    assert!(parse_request(&mut Cursor::new(&full[..]), &clock, u64::MAX, &limits).is_ok());
    for (cut, prefix) in truncation_corpus(&full).enumerate() {
        let got = parse_request(&mut Cursor::new(prefix), &clock, u64::MAX, &limits);
        assert!(got.is_err(), "prefix of {cut} bytes parsed as {got:?}");
    }
}

/// Seeded random mutations (bit flips, inserts, deletes, truncations):
/// any verdict is acceptable, panicking or hanging is not.
#[test]
fn http_parser_survives_seeded_mutations() {
    let full = canonical_request();
    let clock = MockClock::new(0);
    let limits = Limits::default();
    for m in mutation_corpus(&full, 600, 0x177e_eb) {
        let _ = parse_request(&mut Cursor::new(&m[..]), &clock, u64::MAX, &limits);
    }
}

/// The binary wire codec holds the same line against the same corpus
/// drivers — truncations are typed decode errors, mutations never panic.
#[test]
fn wire_codec_shares_the_hostile_corpus_discipline() {
    let msg = Message::InsertAck { seq: 7, accepted: 3, total: 10, sealed_now: 1, sealed_total: 2 };
    let bytes = msg.encode();
    assert_eq!(Message::decode(&bytes).unwrap(), msg);
    for (cut, prefix) in truncation_corpus(&bytes).enumerate() {
        assert!(Message::decode(prefix).is_err(), "prefix of {cut} bytes decoded");
    }
    for m in mutation_corpus(&bytes, 600, 0xC0DEC) {
        let _ = Message::decode(&m);
    }
}

// ---------------------------------------------------------------------------
// Direct path (admission disabled) — also the TIER1_MATRIX leg
// ---------------------------------------------------------------------------

/// Without admission the edge drives `query_batch_flat` directly; an HTTP
/// round trip is bit-identical to the in-process call.
#[test]
fn direct_path_query_is_bit_identical_to_query_batch_flat() {
    let (orch, edge, c) = direct_edge();
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        let r = http_post(edge.addr(), "/v1/query", &query_body(q));
        assert_eq!(r.status, 200, "query {i}: {}", r.body);
        let got = result_from_json(&r.json());
        let want = orch
            .query_batch_flat(q.to_vec(), 1, Budget::none(), Class::Monitor)
            .unwrap()
            .remove(0);
        assert_bit_identical(&got, &want, &format!("HTTP query {i} vs direct call"));
    }
    wait_until(|| edge.stats().query.requests == c.queries.len() as u64, "query counters");
    assert_eq!(edge.stats().query.errors, 0);
}

/// On the direct path the request's `budget_us`/`policy` form the node
/// Budget verbatim: a zero budget under `"partial"` comes back `206`,
/// flagged partial, with zero scan work done.
#[test]
fn direct_path_zero_budget_partial_answer_is_206() {
    let (_orch, edge, c) = direct_edge();
    let mut o = JsonObj::new();
    o.insert("point", point_json(c.queries.point(0)));
    o.insert("budget_us", Json::Num(0.0));
    o.insert("policy", Json::Str("partial".into()));
    let r = http_post(edge.addr(), "/v1/query", &Json::Obj(o).to_string_compact());
    assert_eq!(r.status, 206, "{}", r.body);
    let j = r.json();
    assert_eq!(j.get("partial").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("shed_nodes").unwrap().as_u64(), Some(0), "partial, not shed");
    assert_eq!(j.get("max_comparisons").unwrap().as_u64(), Some(0), "no scan work");
}

/// Health and stats endpoints work without the admission layer: the
/// stats document reports `"admission": null`.
#[test]
fn direct_path_health_and_stats_without_admission() {
    let (_orch, edge, _c) = direct_edge();
    let a = edge.addr();
    let h = http_get(a, "/healthz");
    assert_eq!(h.status, 200);
    assert_eq!(h.json().get("status").unwrap().as_str(), Some("ok"));
    let r = http_get(a, "/readyz");
    assert_eq!(r.status, 200, "{}", r.body);
    let s = http_get(a, "/v1/stats");
    assert_eq!(s.status, 200);
    let j = s.json();
    assert!(matches!(j.get("admission"), Some(Json::Null)), "no admission installed: {}", s.body);
    assert_eq!(j.get("failover").unwrap().get("replicas_down").unwrap().as_u64(), Some(0));
}

// ---------------------------------------------------------------------------
// End-to-end: live replicated cluster + admission behind the edge
// ---------------------------------------------------------------------------

/// The acceptance scenario: a live (streaming) replicated cluster with
/// the admission layer installed, served over HTTP on a port-0 listener.
/// Inserts fan out to both replicas (`replicas_acked` in the response),
/// HTTP queries are bit-identical to direct `submit_class` calls, and
/// stats/health endpoints reflect the traffic.
#[test]
fn e2e_http_serving_matches_direct_submit_on_a_live_replicated_cluster() {
    let c = corpus(240, 6, 21);
    let d = &c.data;
    let params = lsh_params(d, 16, 8, 23);
    let policy = SealPolicy::by_size(100);
    let clock = Arc::new(MockClock::new(0));

    // Two shards × two replicas; replicas share an id base so the same
    // insert stream keeps them interchangeable.
    let sets: Vec<ReplicaSet> = (0..2)
        .map(|shard| {
            let replicas = (0..2)
                .map(|_| {
                    boxed(LocalNode::spawn_live(
                        shard,
                        shard as u64 * LIVE_ID_STRIDE,
                        &params,
                        2,
                        native_engines(2),
                        Arc::new(SystemClock::new()) as Arc<dyn Clock>,
                        policy,
                    ))
                })
                .collect();
            ReplicaSet::new(shard, replicas)
        })
        .collect();
    let mut orch = replicated_orch(sets, params.k, quiet_failover(), &clock);
    orch.enable_admission(AdmissionConfig::new(d.dim, 1));
    let orch = Arc::new(orch);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let cfg = EdgeConfig::new(d.dim);
    let edge = EdgeServer::start(Arc::clone(&orch), listener, cfg).unwrap();
    let a = edge.addr();

    // Liveness and readiness before any data.
    assert_eq!(http_get(a, "/healthz").status, 200);
    let r = http_get(a, "/readyz");
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.json().get("ready").unwrap().as_bool(), Some(true));

    // Ingest the corpus over HTTP; every batch must be acknowledged by
    // both replicas of its target shard.
    let batch = 60;
    let mut at = 0;
    while at < d.len() {
        let take = batch.min(d.len() - at);
        let r = http_post(a, "/v1/insert", &insert_body(d, at, take));
        assert_eq!(r.status, 200, "insert at {at}: {}", r.body);
        let j = r.json();
        assert_eq!(j.get("accepted").unwrap().as_u64(), Some(take as u64));
        assert_eq!(j.get("replicas_acked").unwrap().as_u64(), Some(2), "{}", r.body);
        at += take;
    }
    let ing = orch.ingest_stats();
    assert_eq!((ing.batches, ing.points), (4, 240));

    // HTTP queries through admission are bit-identical to direct submits
    // on the same cluster.
    for i in 0..c.queries.len() {
        let q = c.queries.point(i);
        let r = http_post(a, "/v1/query", &query_body(q));
        assert_eq!(r.status, 200, "query {i}: {}", r.body);
        let got = result_from_json(&r.json());
        let want = orch.submit_class(q, FAR, Class::Monitor).unwrap().wait().unwrap();
        assert_bit_identical(&got, &want, &format!("HTTP query {i} vs submit_class"));
        assert!(!got.partial, "full budget must complete");
    }

    // The stats document reflects all of the above.
    wait_until(|| edge.stats().query.requests == c.queries.len() as u64, "query counters");
    let s = http_get(a, "/v1/stats");
    assert_eq!(s.status, 200);
    let j = s.json();
    assert_eq!(j.get("ingest").unwrap().get("points").unwrap().as_u64(), Some(240));
    assert_eq!(j.get("failover").unwrap().get("replicas_down").unwrap().as_u64(), Some(0));
    let adm = j.get("admission").unwrap();
    // 6 HTTP + 6 direct submits, all completed, none rejected.
    assert_eq!(adm.get("submitted").unwrap().as_u64(), Some(12), "{}", s.body);
    assert_eq!(adm.get("completed").unwrap().as_u64(), Some(12));
    assert_eq!(adm.get("rejected_full").unwrap().as_u64(), Some(0));
    let eq = j.get("edge").unwrap().get("query").unwrap();
    assert_eq!(eq.get("requests").unwrap().as_u64(), Some(6));
    assert_eq!(eq.get("errors").unwrap().as_u64(), Some(0));
}

/// Queue-full backpressure over HTTP, deterministically: with a blocked
/// replica, a capacity-1 queue and a rendezvous pipeline, the fourth
/// concurrent query is turned away at the door — `429`, `Retry-After`,
/// `rejected_full` — and completes normally once capacity frees up.
#[test]
fn queue_full_is_429_with_retry_after() {
    let c = corpus(160, 4, 17);
    let params = lsh_params(&c.data, 8, 4, 5);
    let parts = shard_parts(&c.data, 1);
    let switch = FaultSwitch::new();
    let inner = spawn_replica(&parts[0].1, 0, parts[0].0, &params, 1);
    let clock = Arc::new(MockClock::new(0));
    let sets = vec![ReplicaSet::new(0, vec![boxed(FaultyNode::new(inner, Arc::clone(&switch)))])];
    let mut orch = replicated_orch(sets, params.k, quiet_failover(), &clock);
    orch.enable_admission(AdmissionConfig::new(c.data.dim, 1).with_queue_cap(1).with_pipeline(1));
    let orch = Arc::new(orch);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let cfg = EdgeConfig::new(c.data.dim);
    let edge = EdgeServer::start(Arc::clone(&orch), listener, cfg).unwrap();
    let a = edge.addr();
    let admission = orch.admission().unwrap();

    switch.set(|p| p.block_queries = true);
    let body = query_body(c.queries.point(0));
    let post = |body: String| std::thread::spawn(move || http_post(a, "/v1/query", &body));

    // A: cut immediately (max_batch 1), dispatched, parked at the
    // blocked replica.
    let ta = post(body.clone());
    wait_until(|| switch.requests_seen() == 1, "A to reach the blocked replica");
    // B: cut by the cutter, then parked at the rendezvous handoff behind
    // A (counters record the cut before the blocking send).
    let tb = post(body.clone());
    wait_until(|| admission.stats().completed == 2, "B's cut to be formed");
    // C: fills the queue (capacity 1).
    let tc = post(body.clone());
    wait_until(|| admission.stats().depth == 1, "C to fill the queue");

    // D: turned away at the door with the full backpressure contract.
    let d = http_post(a, "/v1/query", &body);
    assert_eq!(d.status, 429, "{}", d.body);
    assert_eq!(d.error_code(), "queue-full");
    assert_eq!(d.header("Retry-After"), Some("1"));
    assert_eq!(admission.stats().rejected_full, 1);

    // Release the replica: A, B and C all complete with full answers.
    switch.set(|p| p.block_queries = false);
    for (t, name) in [(ta, "A"), (tb, "B"), (tc, "C")] {
        let r = t.join().unwrap();
        assert_eq!(r.status, 200, "{name}: {}", r.body);
    }
    wait_until(|| edge.stats().query.requests == 4, "all four queries counted");
    assert_eq!(edge.stats().query.errors, 1, "only D errored");
}

/// With the queue's enforcement policy set to `PartialResults`, a blown
/// budget comes back over HTTP as a flagged `206` and shows up in the
/// lane's `partials` counter — degraded, never silent.
#[test]
fn admission_blown_budget_is_a_flagged_206() {
    let c = corpus(160, 2, 13);
    let params = lsh_params(&c.data, 8, 4, 5);
    let mut orch = reference_orchestrator(&c.data, &params, 2, 1);
    orch.enable_admission(
        AdmissionConfig::new(c.data.dim, 1).with_budget_policy(BudgetPolicy::PartialResults),
    );
    let orch = Arc::new(orch);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let cfg = EdgeConfig::new(c.data.dim);
    let edge = EdgeServer::start(Arc::clone(&orch), listener, cfg).unwrap();

    let mut o = JsonObj::new();
    o.insert("point", point_json(c.queries.point(0)));
    o.insert("budget_us", Json::Num(0.0));
    let r = http_post(edge.addr(), "/v1/query", &Json::Obj(o).to_string_compact());
    assert_eq!(r.status, 206, "{}", r.body);
    let j = r.json();
    assert_eq!(j.get("partial").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("shed_nodes").unwrap().as_u64(), Some(0), "partial, not shed");
    let stats = orch.admission().unwrap().stats();
    assert!(stats.monitor.partials >= 1, "the partial answer is metered: {stats:?}");
}

/// `/readyz` follows the PR 6 failure detector's replica gauge: a dead
/// replica flips it to `503 not-ready`, a successful reconnect flips it
/// back — so a load balancer drains a degraded edge and restores it.
#[test]
fn readyz_tracks_the_replica_down_gauge() {
    let c = corpus(160, 2, 9);
    let params = lsh_params(&c.data, 8, 4, 5);
    let parts = shard_parts(&c.data, 1);
    let switch = FaultSwitch::new();
    let faulty =
        FaultyNode::new(spawn_replica(&parts[0].1, 0, parts[0].0, &params, 1), Arc::clone(&switch));
    let healthy = spawn_replica(&parts[0].1, 0, parts[0].0, &params, 1);
    let clock = Arc::new(MockClock::new(0));
    let sets = vec![ReplicaSet::new(0, vec![boxed(faulty), boxed(healthy)])];
    let orch = Arc::new(replicated_orch(sets, params.k, quiet_failover(), &clock));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let cfg = EdgeConfig::new(c.data.dim);
    let edge = EdgeServer::start(Arc::clone(&orch), listener, cfg).unwrap();
    let a = edge.addr();

    assert_eq!(http_get(a, "/readyz").status, 200);

    // Kill the primary (reconnects fail too): the next query fails over
    // to the sibling — still a 200 — but the detector marks the replica
    // Down and readiness flips.
    switch.set(|p| {
        p.fail_requests = true;
        p.fail_reconnects = true;
    });
    let q = http_post(a, "/v1/query", &query_body(c.queries.point(0)));
    assert_eq!(q.status, 200, "failover keeps serving: {}", q.body);
    wait_until(|| orch.failover_stats().replicas_down == 1, "the down transition");
    let r = http_get(a, "/readyz");
    assert_eq!((r.status, r.error_code().as_str()), (503, "not-ready"));

    // Revive the replica and let the backoff'd reconnect succeed: the
    // gauge returns to zero and readiness recovers.
    switch.set(|p| {
        p.fail_requests = false;
        p.fail_reconnects = false;
    });
    wait_until(
        || {
            clock.advance(Duration::from_millis(5));
            orch.failover_stats().reconnects == 1
        },
        "the reconnect to succeed",
    );
    wait_until(|| orch.failover_stats().replicas_down == 0, "the gauge to recover");
    let r = http_get(a, "/readyz");
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.json().get("replicas_down").unwrap().as_u64(), Some(0));
}
