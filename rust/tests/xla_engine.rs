//! Integration: the AOT JAX/Pallas artifacts executed via PJRT must agree
//! with the native Rust engine on the same candidate scans. Requires
//! `make artifacts` (the Makefile test target guarantees it).

use dslsh::engine::native::NativeEngine;
use dslsh::engine::{DistanceEngine, Metric};
use dslsh::knn::TopK;
use dslsh::runtime::XlaService;
use dslsh::util::rng::Xoshiro256;

fn fixture(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let data = (0..n * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    let labels = (0..n).map(|_| rng.gen_bool(0.1)).collect();
    let q = (0..dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
    (data, labels, q)
}

#[test]
#[cfg_attr(
    not(feature = "xla"),
    ignore = "requires --features xla (PJRT runtime is stubbed offline) and `make artifacts`"
)]
fn xla_engine_matches_native_engine() {
    let service = match XlaService::start() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: XLA runtime unavailable ({e:#})");
            return;
        }
    };
    let xla = service.engine();
    let native = NativeEngine::new();
    let (data, labels, q) = fixture(5000, 30, 1);
    let mut rng = Xoshiro256::seed_from_u64(2);
    for metric in [Metric::L1, Metric::Cosine] {
        // Candidate counts spanning the batch ladder, incl. padding edges
        // and chunking beyond the largest compiled batch.
        for &count in &[1usize, 7, 255, 256, 257, 2048, 4999] {
            let ids: Vec<u32> = (0..count).map(|_| rng.gen_below(5000) as u32).collect();
            let mut t_native = TopK::new(10);
            let mut t_xla = TopK::new(10);
            let c1 = native.scan(metric, &q, &data, 30, &ids, &labels, 0, &mut t_native);
            let c2 = xla.scan(metric, &q, &data, 30, &ids, &labels, 0, &mut t_xla);
            assert_eq!(c1, c2);
            let a = t_native.into_sorted();
            let b = t_xla.into_sorted();
            assert_eq!(a.len(), b.len(), "metric={metric:?} count={count}");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id, "metric={metric:?} count={count}");
                assert!((x.dist - y.dist).abs() < 1e-2, "{} vs {}", x.dist, y.dist);
                assert_eq!(x.label, y.label);
            }
        }
    }
}

#[test]
#[cfg_attr(
    not(feature = "xla"),
    ignore = "requires --features xla (PJRT runtime is stubbed offline) and `make artifacts`"
)]
fn xla_engine_is_usable_from_multiple_threads() {
    let service = match XlaService::start() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: XLA runtime unavailable ({e:#})");
            return;
        }
    };
    let (data, labels, q) = fixture(2000, 30, 3);
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let engine = service.engine();
            let (data, labels, q) = (&data, &labels, &q);
            s.spawn(move || {
                let mut rng = Xoshiro256::seed_from_u64(100 + t);
                for _ in 0..5 {
                    let ids: Vec<u32> =
                        (0..300).map(|_| rng.gen_below(2000) as u32).collect();
                    let mut topk = TopK::new(5);
                    let c = engine.scan(Metric::L1, q, data, 30, &ids, labels, 0, &mut topk);
                    assert_eq!(c, 300);
                    assert_eq!(topk.len(), 5);
                }
            });
        }
    });
}
