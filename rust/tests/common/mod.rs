//! Shared helpers for the integration-test suites (admission parity,
//! priority lanes, budget enforcement, distributed runtime): corpus +
//! parameter fixtures, TCP cluster spawning, the gated-dispatcher
//! harness, and the bit-identity assertion. One copy, four suites — a
//! new scheduling test should never re-implement these.
//!
//! Compiled once per test binary; not every binary uses every helper.
#![allow(dead_code)]

use std::net::TcpListener;
use std::sync::mpsc::{Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use dslsh::coordinator::admission::{Budget, Class};
use dslsh::coordinator::orchestrator::{NodeHandle, Orchestrator};
use dslsh::coordinator::QueryResult;
use dslsh::data::{build_corpus, Corpus, CorpusConfig, Dataset, WindowSpec};
use dslsh::engine::native::NativeEngine;
use dslsh::engine::DistanceEngine;
use dslsh::knn::predict::VoteConfig;
use dslsh::lsh::family::LayerSpec;
use dslsh::net::{serve_node, RemoteNode};
use dslsh::slsh::{SealPolicy, SlshParams, LIVE_ID_STRIDE};
use dslsh::util::threadpool::chunk_ranges;

/// Budgets a frozen MockClock can never expire.
pub const FAR: Duration = Duration::from_secs(3600);

/// AHE-51-5c corpus fixture (`n` points, `nq` queries).
pub fn corpus(n: usize, nq: usize, seed: u64) -> Corpus {
    build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), n, nq, seed))
}

/// LSH-only SLSH parameters over `data`'s value range, K = 10.
pub fn lsh_params(data: &Dataset, m: usize, l: usize, seed: u64) -> SlshParams {
    let (lo, hi) = data.value_range();
    SlshParams::lsh_only(LayerSpec::outer_l1(data.dim, m, l, lo, hi, seed), 10)
}

/// One native engine per core — the node-spawning boilerplate.
pub fn native_engines(p: usize) -> Vec<Box<dyn DistanceEngine>> {
    (0..p).map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>).collect()
}

/// Everything in a `QueryResult` that is workload-determined. `qid` is
/// arrival-order (scheduler-dependent through the queue) and `latency_s`
/// is wall-clock; both are excluded by construction.
pub fn assert_bit_identical(got: &QueryResult, want: &QueryResult, ctx: &str) {
    assert_eq!(got.neighbors, want.neighbors, "{ctx}: neighbors");
    assert!(
        got.positive_share == want.positive_share,
        "{ctx}: positive_share {} != {}",
        got.positive_share,
        want.positive_share
    );
    assert_eq!(got.prediction, want.prediction, "{ctx}: prediction");
    assert_eq!(got.max_comparisons, want.max_comparisons, "{ctx}: max_comparisons");
    assert_eq!(
        got.per_node_comparisons, want.per_node_comparisons,
        "{ctx}: per_node_comparisons"
    );
    assert_eq!(got.partial, want.partial, "{ctx}: partial flag");
    assert_eq!(got.shed_nodes, want.shed_nodes, "{ctx}: shed_nodes");
}

/// Spin (bounded by real time) until a counter condition holds — cutter
/// and dispatcher threads need a moment to act on a notify or a clock
/// advance; the *outcome* waited for is deterministic, only its arrival
/// time is scheduler-dependent.
pub fn wait_until(mut cond: impl FnMut() -> bool, what: &str) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
        std::thread::yield_now();
    }
}

/// A minimal `QueryResult` echoing `share` in `positive_share` — for
/// fake dispatchers proving ticket↔result alignment.
pub fn echo_result(qid: u64, share: f64) -> QueryResult {
    QueryResult {
        qid,
        neighbors: Vec::new(),
        positive_share: share,
        prediction: false,
        max_comparisons: 0,
        per_node_comparisons: Vec::new(),
        latency_s: 0.0,
        partial: false,
        shed_nodes: 0,
    }
}

/// Gated dispatcher used by the scheduling-semantics tests: reports each
/// batch's flat payload on `evt_tx` (dim = 1, so the payload identifies
/// the batch composition), then blocks until the test releases it through
/// `gate_rx` — an in-flight batch the test fully controls. Results echo
/// each query's coordinate in `positive_share`.
pub fn gated_echo(
    evt_tx: Sender<Vec<f32>>,
    gate_rx: Receiver<()>,
) -> impl FnMut(Vec<f32>, usize, Budget, Class) -> Vec<QueryResult> + Send + 'static {
    move |flat: Vec<f32>, nq: usize, _budget: Budget, _class: Class| {
        evt_tx.send(flat.clone()).unwrap();
        gate_rx.recv().unwrap();
        (0..nq).map(|i| echo_result(i as u64, flat[i] as f64)).collect()
    }
}

/// Spawn a TCP loopback cluster over `data`: one port-0 listener + server
/// thread per node (parallel-safe under the concurrent test runner), one
/// connected [`RemoteNode`] each, wrapped in a started [`Orchestrator`].
/// Join the returned server handles after dropping the orchestrator to
/// assert per-server query accounting.
pub fn tcp_cluster(
    data: &Dataset,
    params: &SlshParams,
    nu: usize,
    cores: usize,
) -> (Orchestrator, Vec<JoinHandle<u64>>) {
    let ranges = chunk_ranges(data.len(), nu);
    tcp_cluster_with(params, nu, |node_id, addr| {
        let range = ranges[node_id].clone();
        let shard = data.shard(range.clone());
        RemoteNode::connect(addr, node_id, shard, range.start as u64, params, cores).unwrap()
    })
}

/// Spawn an EMPTY live TCP loopback cluster: one port-0 listener +
/// server thread per node, one `connect_live`-built [`RemoteNode`] each
/// (id bases strided like `build_live_cluster`'s), wrapped in a started
/// [`Orchestrator`] ready for `insert_batch` routing with acks crossing
/// the wire.
pub fn tcp_live_cluster(
    params: &SlshParams,
    nu: usize,
    cores: usize,
    policy: SealPolicy,
) -> (Orchestrator, Vec<JoinHandle<u64>>) {
    tcp_cluster_with(params, nu, |node_id, addr| {
        RemoteNode::connect_live(
            addr,
            node_id,
            node_id as u64 * LIVE_ID_STRIDE,
            params,
            cores,
            policy,
        )
        .unwrap()
    })
}

/// Shared TCP-cluster scaffolding: port-0 listeners + one server thread
/// per node, nodes built by `connect` (batch `RemoteNode::connect` or
/// live `connect_live`), wrapped in a started [`Orchestrator`].
fn tcp_cluster_with(
    params: &SlshParams,
    nu: usize,
    mut connect: impl FnMut(usize, std::net::SocketAddr) -> RemoteNode,
) -> (Orchestrator, Vec<JoinHandle<u64>>) {
    let mut listeners = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..nu {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(l.local_addr().unwrap());
        listeners.push(l);
    }
    let servers: Vec<JoinHandle<u64>> = listeners
        .into_iter()
        .map(|l| std::thread::spawn(move || serve_node(&l, None).unwrap()))
        .collect();
    let nodes: Vec<Box<dyn NodeHandle>> = (0..nu)
        .map(|node_id| Box::new(connect(node_id, addrs[node_id])) as Box<dyn NodeHandle>)
        .collect();
    (Orchestrator::start(nodes, params.k, VoteConfig::default()), servers)
}
