//! Shared helpers for the integration-test suites (admission parity,
//! priority lanes, budget enforcement, distributed runtime, fault
//! tolerance): corpus + parameter fixtures, TCP cluster spawning, the
//! gated-dispatcher harness, the fault-injection node double, and the
//! bit-identity assertion. One copy, five suites — a new scheduling or
//! failover test should never re-implement these.
//!
//! Compiled once per test binary; not every binary uses every helper.
#![allow(dead_code)]

use std::net::TcpListener;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use dslsh::coordinator::admission::{Budget, Class};
use dslsh::coordinator::orchestrator::{ClusterError, NodeError, NodeHandle, Orchestrator};
use dslsh::coordinator::{Clock, FailoverConfig, MockClock, QueryResult, ReplicaSet};
use dslsh::data::{build_corpus, Corpus, CorpusConfig, Dataset, WindowSpec};
use dslsh::engine::native::NativeEngine;
use dslsh::engine::DistanceEngine;
use dslsh::knn::predict::VoteConfig;
use dslsh::lsh::family::LayerSpec;
use dslsh::lsh::probe::ProbeSpec;
use dslsh::net::{serve_node, RemoteNode};
use dslsh::node::node::{HeartbeatReply, InsertReply, LocalNode, NodeInfo, NodeReply};
use dslsh::slsh::{SealPolicy, SlshParams, LIVE_ID_STRIDE};
use dslsh::util::threadpool::chunk_ranges;

/// Budgets a frozen MockClock can never expire.
pub const FAR: Duration = Duration::from_secs(3600);

/// AHE-51-5c corpus fixture (`n` points, `nq` queries).
pub fn corpus(n: usize, nq: usize, seed: u64) -> Corpus {
    build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), n, nq, seed))
}

/// LSH-only SLSH parameters over `data`'s value range, K = 10.
pub fn lsh_params(data: &Dataset, m: usize, l: usize, seed: u64) -> SlshParams {
    let (lo, hi) = data.value_range();
    SlshParams::lsh_only(LayerSpec::outer_l1(data.dim, m, l, lo, hi, seed), 10)
}

/// One native engine per core — the node-spawning boilerplate.
pub fn native_engines(p: usize) -> Vec<Box<dyn DistanceEngine>> {
    (0..p).map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>).collect()
}

/// Everything in a `QueryResult` that is workload-determined. `qid` is
/// arrival-order (scheduler-dependent through the queue) and `latency_s`
/// is wall-clock; both are excluded by construction.
pub fn assert_bit_identical(got: &QueryResult, want: &QueryResult, ctx: &str) {
    assert_eq!(got.neighbors, want.neighbors, "{ctx}: neighbors");
    assert!(
        got.positive_share == want.positive_share,
        "{ctx}: positive_share {} != {}",
        got.positive_share,
        want.positive_share
    );
    assert_eq!(got.prediction, want.prediction, "{ctx}: prediction");
    assert_eq!(got.max_comparisons, want.max_comparisons, "{ctx}: max_comparisons");
    assert_eq!(
        got.per_node_comparisons, want.per_node_comparisons,
        "{ctx}: per_node_comparisons"
    );
    assert_eq!(got.partial, want.partial, "{ctx}: partial flag");
    assert_eq!(got.shed_nodes, want.shed_nodes, "{ctx}: shed_nodes");
}

/// Spin (bounded by real time) until a counter condition holds — cutter
/// and dispatcher threads need a moment to act on a notify or a clock
/// advance; the *outcome* waited for is deterministic, only its arrival
/// time is scheduler-dependent.
pub fn wait_until(mut cond: impl FnMut() -> bool, what: &str) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
        std::thread::yield_now();
    }
}

/// A minimal `QueryResult` echoing `share` in `positive_share` — for
/// fake dispatchers proving ticket↔result alignment.
pub fn echo_result(qid: u64, share: f64) -> QueryResult {
    QueryResult {
        qid,
        neighbors: Vec::new(),
        positive_share: share,
        prediction: false,
        max_comparisons: 0,
        per_node_comparisons: Vec::new(),
        latency_s: 0.0,
        partial: false,
        shed_nodes: 0,
    }
}

/// Gated dispatcher used by the scheduling-semantics tests: reports each
/// batch's flat payload on `evt_tx` (dim = 1, so the payload identifies
/// the batch composition), then blocks until the test releases it through
/// `gate_rx` — an in-flight batch the test fully controls. Results echo
/// each query's coordinate in `positive_share`.
pub fn gated_echo(
    evt_tx: Sender<Vec<f32>>,
    gate_rx: Receiver<()>,
) -> impl FnMut(
    Vec<f32>,
    usize,
    Budget,
    Class,
    ProbeSpec,
    u64,
) -> Result<Vec<QueryResult>, ClusterError>
       + Send
       + 'static {
    move |flat: Vec<f32>,
          nq: usize,
          _budget: Budget,
          _class: Class,
          _probe: ProbeSpec,
          _trace: u64| {
        evt_tx.send(flat.clone()).unwrap();
        gate_rx.recv().unwrap();
        Ok((0..nq).map(|i| echo_result(i as u64, flat[i] as f64)).collect())
    }
}

/// Spawn a TCP loopback cluster over `data`: one port-0 listener + server
/// thread per node (parallel-safe under the concurrent test runner), one
/// connected [`RemoteNode`] each, wrapped in a started [`Orchestrator`].
/// Join the returned server handles after dropping the orchestrator to
/// assert per-server query accounting.
pub fn tcp_cluster(
    data: &Dataset,
    params: &SlshParams,
    nu: usize,
    cores: usize,
) -> (Orchestrator, Vec<JoinHandle<u64>>) {
    let ranges = chunk_ranges(data.len(), nu);
    tcp_cluster_with(params, nu, |node_id, addr| {
        let range = ranges[node_id].clone();
        let shard = data.shard(range.clone());
        RemoteNode::connect(addr, node_id, shard, range.start as u64, params, cores).unwrap()
    })
}

/// Spawn an EMPTY live TCP loopback cluster: one port-0 listener +
/// server thread per node, one `connect_live`-built [`RemoteNode`] each
/// (id bases strided like `build_live_cluster`'s), wrapped in a started
/// [`Orchestrator`] ready for `insert_batch` routing with acks crossing
/// the wire.
pub fn tcp_live_cluster(
    params: &SlshParams,
    nu: usize,
    cores: usize,
    policy: SealPolicy,
) -> (Orchestrator, Vec<JoinHandle<u64>>) {
    tcp_cluster_with(params, nu, |node_id, addr| {
        RemoteNode::connect_live(
            addr,
            node_id,
            node_id as u64 * LIVE_ID_STRIDE,
            params,
            cores,
            policy,
        )
        .unwrap()
    })
}

/// Failover policy whose timers can only be driven by an explicit
/// `MockClock` advance: hedge, request timeout and heartbeat are parked
/// at [`FAR`] (override the field under test); reconnect backoff is
/// 10 ms · 2ⁿ capped at 160 ms with ZERO jitter, so attempt due-times
/// are exact clock values the fault suite can step right up to.
pub fn quiet_failover() -> FailoverConfig {
    FailoverConfig {
        hedge_after: FAR,
        request_timeout: FAR,
        heartbeat_every: FAR,
        reconnect_base: Duration::from_millis(10),
        reconnect_cap: Duration::from_millis(160),
        reconnect_jitter: 0.0,
        seed: 7,
    }
}

/// Shard `data` into `nu` contiguous parts as `(id_base, shared slice)`
/// pairs — the inputs every replica of a shard must share so their
/// tables come out bit-identical.
pub fn shard_parts(data: &Dataset, nu: usize) -> Vec<(u64, Arc<Dataset>)> {
    chunk_ranges(data.len(), nu)
        .into_iter()
        .map(|r| (r.start as u64, Arc::new(data.shard(r))))
        .collect()
}

/// One batch-built [`LocalNode`] replica over a shared shard slice.
pub fn spawn_replica(
    shard: &Arc<Dataset>,
    node_id: usize,
    id_base: u64,
    params: &SlshParams,
    cores: usize,
) -> LocalNode {
    LocalNode::spawn(node_id, Arc::clone(shard), id_base, params, cores, native_engines(cores))
}

/// Unreplicated orchestrator over the same shard layout the replicated
/// builds use — the bit-identity baseline for the fault-tolerance suite.
pub fn reference_orchestrator(
    data: &Dataset,
    params: &SlshParams,
    nu: usize,
    cores: usize,
) -> Orchestrator {
    let nodes: Vec<Box<dyn NodeHandle>> = shard_parts(data, nu)
        .into_iter()
        .enumerate()
        .map(|(i, (base, shard))| {
            Box::new(spawn_replica(&shard, i, base, params, cores)) as Box<dyn NodeHandle>
        })
        .collect();
    Orchestrator::start(nodes, params.k, VoteConfig::default())
}

/// Start a replicated orchestrator under an injected [`MockClock`] — the
/// boilerplate every fault test shares.
pub fn replicated_orch(
    sets: Vec<ReplicaSet>,
    k: usize,
    cfg: FailoverConfig,
    clock: &Arc<MockClock>,
) -> Orchestrator {
    Orchestrator::start_replicated_with_clock(
        sets,
        k,
        VoteConfig::default(),
        cfg,
        Arc::clone(clock) as Arc<dyn Clock>,
    )
}

/// Erase a concrete node into the `Box<dyn NodeHandle>` the replica-set
/// constructors take.
pub fn boxed(node: impl NodeHandle + 'static) -> Box<dyn NodeHandle> {
    Box::new(node)
}

/// One [`ReplicaSet`] per shard part, replicas minted by `make` — which
/// receives `(shard, id_base, slice)` and returns the boxed replicas.
pub fn replica_sets(
    parts: &[(u64, Arc<Dataset>)],
    mut make: impl FnMut(usize, u64, &Arc<Dataset>) -> Vec<Box<dyn NodeHandle>>,
) -> Vec<ReplicaSet> {
    parts
        .iter()
        .enumerate()
        .map(|(shard, (base, slice))| ReplicaSet::new(shard, make(shard, *base, slice)))
        .collect()
}

/// Mutable fault program for a [`FaultyNode`], shared between the test
/// and the replica runner thread that owns the node. Flip the switches
/// mid-run to kill, stall or revive a replica while the cluster serves.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Queries, batches and inserts return `Err` (heartbeats too — a
    /// dead node answers nothing).
    pub fail_requests: bool,
    /// Requests block (releasably) instead of answering — a straggler,
    /// not a corpse; forces hedges without real sleeps.
    pub block_queries: bool,
    /// `reconnect()` returns `Err` (the replica is still unreachable).
    pub fail_reconnects: bool,
    /// Requests that reached the node (queries, batches, inserts).
    pub requests_seen: u64,
    /// Reconnect attempts that reached the node.
    pub reconnects_seen: u64,
}

/// Shared handle to a [`FaultPlan`]: the test flips switches, the node
/// (on its runner thread) observes them; the condvar wakes requests
/// parked by `block_queries`.
pub struct FaultSwitch {
    plan: Mutex<FaultPlan>,
    released: Condvar,
}

impl FaultSwitch {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<FaultSwitch> {
        Arc::new(FaultSwitch { plan: Mutex::new(FaultPlan::default()), released: Condvar::new() })
    }

    /// Atomically edit the plan and wake any blocked requests.
    pub fn set(&self, edit: impl FnOnce(&mut FaultPlan)) {
        let mut plan = self.plan.lock().unwrap();
        edit(&mut plan);
        self.released.notify_all();
    }

    pub fn requests_seen(&self) -> u64 {
        self.plan.lock().unwrap().requests_seen
    }

    pub fn reconnects_seen(&self) -> u64 {
        self.plan.lock().unwrap().reconnects_seen
    }
}

/// A [`NodeHandle`] test double wrapping a real [`LocalNode`]: healthy by
/// default (bit-identical answers to its inner node), it fails or blocks
/// requests on command through its [`FaultSwitch`] — the deterministic
/// stand-in for a crashed or straggling replica. Blocking is bounded
/// (10 s real time) so a test bug cannot wedge a runner thread forever.
pub struct FaultyNode {
    inner: LocalNode,
    switch: Arc<FaultSwitch>,
}

impl FaultyNode {
    pub fn new(inner: LocalNode, switch: Arc<FaultSwitch>) -> FaultyNode {
        FaultyNode { inner, switch }
    }

    /// Count the request, park while `block_queries` holds, then fail if
    /// `fail_requests` holds.
    fn gate(&self) -> Result<(), NodeError> {
        let mut plan = self.switch.plan.lock().unwrap();
        plan.requests_seen += 1;
        let t0 = Instant::now();
        while plan.block_queries {
            assert!(t0.elapsed() < Duration::from_secs(10), "blocked replica never released");
            let (p, _) =
                self.switch.released.wait_timeout(plan, Duration::from_millis(50)).unwrap();
            plan = p;
        }
        if plan.fail_requests {
            Err(NodeError::new(LocalNode::node_id(&self.inner), "injected fault"))
        } else {
            Ok(())
        }
    }
}

impl NodeHandle for FaultyNode {
    fn node_id(&self) -> usize {
        LocalNode::node_id(&self.inner)
    }

    fn info(&self) -> NodeInfo {
        self.inner.info().clone()
    }

    fn query(&mut self, q: &[f32]) -> Result<NodeReply, NodeError> {
        self.gate()?;
        Ok(self.inner.query(q))
    }

    fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Result<Vec<NodeReply>, NodeError> {
        self.gate()?;
        Ok(self.inner.query_batch(qs, nq))
    }

    fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Result<Vec<NodeReply>, NodeError> {
        self.gate()?;
        Ok(self.inner.query_batch_budget(qs, nq, budget, class))
    }

    fn insert_batch(&mut self, points: &[f32], labels: &[bool]) -> Result<InsertReply, NodeError> {
        self.gate()?;
        Ok(self.inner.insert_batch(points, labels))
    }

    fn heartbeat(&mut self) -> Result<HeartbeatReply, NodeError> {
        // Heartbeats share the failure switch (a dead node answers
        // nothing) but never block or count: they are the detector's
        // traffic, not the workload's.
        if self.switch.plan.lock().unwrap().fail_requests {
            return Err(NodeError::new(LocalNode::node_id(&self.inner), "injected fault"));
        }
        NodeHandle::heartbeat(&mut self.inner)
    }

    fn reconnect(&mut self) -> Result<(), NodeError> {
        let mut plan = self.switch.plan.lock().unwrap();
        plan.reconnects_seen += 1;
        if plan.fail_reconnects {
            Err(NodeError::new(LocalNode::node_id(&self.inner), "injected reconnect fault"))
        } else {
            Ok(())
        }
    }
}

/// Shared TCP-cluster scaffolding: port-0 listeners + one server thread
/// per node, nodes built by `connect` (batch `RemoteNode::connect` or
/// live `connect_live`), wrapped in a started [`Orchestrator`].
fn tcp_cluster_with(
    params: &SlshParams,
    nu: usize,
    mut connect: impl FnMut(usize, std::net::SocketAddr) -> RemoteNode,
) -> (Orchestrator, Vec<JoinHandle<u64>>) {
    let mut listeners = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..nu {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(l.local_addr().unwrap());
        listeners.push(l);
    }
    let servers: Vec<JoinHandle<u64>> = listeners
        .into_iter()
        .map(|l| std::thread::spawn(move || serve_node(&l, None).unwrap()))
        .collect();
    let nodes: Vec<Box<dyn NodeHandle>> = (0..nu)
        .map(|node_id| Box::new(connect(node_id, addrs[node_id])) as Box<dyn NodeHandle>)
        .collect();
    (Orchestrator::start(nodes, params.k, VoteConfig::default()), servers)
}

// ---------------------------------------------------------------------------
// Hostile-input corpus drivers (shared by the HTTP parser and the binary
// wire codec — one discipline, two codecs)
// ---------------------------------------------------------------------------

/// Every strict prefix of `payload`: the truncation-at-every-byte corpus.
/// A parser under test must return a typed error (never panic, never
/// succeed) on each one.
pub fn truncation_corpus(payload: &[u8]) -> impl Iterator<Item = &[u8]> + '_ {
    (0..payload.len()).map(move |cut| &payload[..cut])
}

/// Seeded fuzz corpus: `rounds` random mutations of `payload`, each a
/// stack of 1–4 edits (bit flip, byte insert, byte delete,
/// truncate-at-random-offset). Deterministic in `seed`, so a CI failure
/// reproduces locally byte-for-byte. A parser under test may accept or
/// reject each mutant — it just must not panic or hang.
pub fn mutation_corpus(payload: &[u8], rounds: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = dslsh::util::rng::Xoshiro256::seed_from_u64(seed);
    let mut out = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let mut m = payload.to_vec();
        let edits = 1 + rng.gen_below(4) as usize;
        for _ in 0..edits {
            if m.is_empty() {
                break;
            }
            match rng.gen_below(4) {
                0 => {
                    let i = rng.gen_index(m.len());
                    m[i] ^= 1 << rng.gen_below(8);
                }
                1 => {
                    let i = rng.gen_index(m.len() + 1);
                    m.insert(i, rng.next_u64() as u8);
                }
                2 => {
                    let i = rng.gen_index(m.len());
                    m.remove(i);
                }
                _ => {
                    let i = rng.gen_index(m.len() + 1);
                    m.truncate(i);
                }
            }
        }
        out.push(m);
    }
    out
}

// ---------------------------------------------------------------------------
// Minimal HTTP test client (the edge speaks one request per connection
// and frames responses on close, so a blocking read-to-EOF client is
// complete)
// ---------------------------------------------------------------------------

/// One parsed HTTP response from the serving edge.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpResponse {
    /// First value of `name`, case-insensitive.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON (panics on non-JSON — use in tests that
    /// expect the typed-body contract to hold).
    pub fn json(&self) -> dslsh::util::json::Json {
        dslsh::util::json::Json::parse(&self.body)
            .unwrap_or_else(|e| panic!("non-JSON body {:?}: {e}", self.body))
    }

    /// The `error.code` field of a typed error body.
    pub fn error_code(&self) -> String {
        self.json()
            .get("error")
            .and_then(|e| e.get("code"))
            .and_then(|c| c.as_str())
            .unwrap_or_else(|| panic!("no error.code in {:?}", self.body))
            .to_string()
    }
}

/// Send raw bytes to the edge, half-close, and read the full response.
/// Write errors are tolerated (the server may reject and close while the
/// client is still sending — e.g. an oversized head); a missing response
/// is not.
pub fn http_send_raw(addr: std::net::SocketAddr, bytes: &[u8]) -> HttpResponse {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    let _ = s.write_all(bytes);
    let _ = s.shutdown(std::net::Shutdown::Write);
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    parse_http_response(&buf)
}

/// `POST path` with a JSON body.
pub fn http_post(addr: std::net::SocketAddr, path: &str, body: &str) -> HttpResponse {
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    http_send_raw(addr, req.as_bytes())
}

/// `GET path`.
pub fn http_get(addr: std::net::SocketAddr, path: &str) -> HttpResponse {
    http_send_raw(addr, format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").as_bytes())
}

/// Parse a complete close-framed HTTP response.
pub fn parse_http_response(buf: &[u8]) -> HttpResponse {
    let text = std::str::from_utf8(buf).expect("response is UTF-8");
    let head_end = text.find("\r\n\r\n").expect("complete response head");
    let mut lines = text[..head_end].split("\r\n");
    let status_line = lines.next().unwrap();
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {status_line:?}"));
    let headers = lines
        .map(|l| {
            let (k, v) = l.split_once(':').unwrap_or_else(|| panic!("bad header {l:?}"));
            (k.to_string(), v.trim().to_string())
        })
        .collect();
    HttpResponse { status, headers, body: text[head_end + 4..].to_string() }
}
