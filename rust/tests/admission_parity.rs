//! Admission-queue parity: queries coalesced into shared cuts by the
//! deadline-aware admission layer must resolve bit-identically to
//! sequential `Orchestrator::query` — across batch caps, latency budgets,
//! scheduling classes (every run stripes submissions over BOTH the
//! monitor and analytics lanes), and cluster sizes, with genuinely
//! concurrent submitters.
//!
//! The batch compositions the cutter produces are scheduler-dependent
//! (that is the point of the test: whatever cuts happen — and whichever
//! lane a query waited in — results must not change); all assertions are
//! value assertions, never timing assertions.

// The positional submit/query entry points are deprecated shims over the
// QuerySpec API; this file exercises them on purpose (they must keep
// working bit-identically until removal).
#![allow(deprecated)]

mod common;

use std::time::Duration;

use common::{assert_bit_identical, corpus as make_corpus, lsh_params};
use dslsh::coordinator::{
    build_cluster, AdmissionConfig, Class, ClusterConfig, QueryResult, Ticket,
};
use dslsh::data::Corpus;

const SUBMITTERS: usize = 4;

fn corpus() -> Corpus {
    make_corpus(2500, 24, 99)
}

#[test]
fn admission_matches_sequential_across_configs() {
    let c = corpus();
    let p = lsh_params(&c.data, 40, 12, 13);
    let nq = c.queries.len();

    for nodes in [1usize, 2, 4] {
        // Reference: sequential queries on one cluster. Same params + same
        // topology on a fresh cluster reproduce the exact same tables, so
        // a second cluster serves the admission side without the two
        // streams perturbing each other's qid sequences.
        let reference = build_cluster(&c.data, &p, &ClusterConfig::new(nodes, 2)).unwrap();
        let seq: Vec<QueryResult> = (0..nq).map(|i| reference.query(c.queries.point(i)).unwrap()).collect();
        let mut under_test = build_cluster(&c.data, &p, &ClusterConfig::new(nodes, 2)).unwrap();

        for max_batch in [1usize, 4, 16] {
            for budget_ms in [0u64, 1, 10] {
                under_test
                    .orchestrator
                    .enable_admission(AdmissionConfig::new(c.data.dim, max_batch).with_queue_cap(64));
                let orch = &under_test.orchestrator;
                let budget = Duration::from_millis(budget_ms);
                let ctx = format!("nodes={nodes} max_batch={max_batch} budget={budget_ms}ms");

                // Concurrent submitters, striped over the query stream
                // AND over both scheduling lanes (even queries ride the
                // monitor lane, odd ones the analytics lane — whatever
                // lane a query waits in, its result must not change).
                // Each thread bursts all its submissions first (letting
                // fill cuts coalesce across threads), then waits.
                let results: Vec<(usize, QueryResult)> = std::thread::scope(|s| {
                    let handles: Vec<_> = (0..SUBMITTERS)
                        .map(|t| {
                            let c = &c;
                            s.spawn(move || {
                                let tickets: Vec<(usize, Ticket)> = (t..nq)
                                    .step_by(SUBMITTERS)
                                    .map(|i| {
                                        let class = if i % 2 == 0 {
                                            Class::Monitor
                                        } else {
                                            Class::Analytics
                                        };
                                        (
                                            i,
                                            orch.submit_class(
                                                c.queries.point(i),
                                                budget,
                                                class,
                                            )
                                            .unwrap(),
                                        )
                                    })
                                    .collect();
                                tickets
                                    .into_iter()
                                    .map(|(i, ticket)| (i, ticket.wait().unwrap()))
                                    .collect::<Vec<_>>()
                            })
                        })
                        .collect();
                    handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
                });

                assert_eq!(results.len(), nq, "{ctx}: every submission must resolve");
                for (i, got) in &results {
                    assert_bit_identical(got, &seq[*i], &format!("{ctx} q={i}"));
                }

                let st = orch.admission().unwrap().stats();
                assert_eq!(st.submitted, nq as u64, "{ctx}: admitted count");
                assert_eq!(st.completed, nq as u64, "{ctx}: completed count");
                assert_eq!(st.depth, 0, "{ctx}: queue drained");
                // The lane split must account for every request: even
                // indices rode the monitor lane, odd the analytics lane.
                assert_eq!(st.monitor.submitted, nq.div_ceil(2) as u64, "{ctx}: monitor lane");
                assert_eq!(st.analytics.submitted, (nq / 2) as u64, "{ctx}: analytics lane");
                assert_eq!(
                    st.monitor.depth + st.analytics.depth,
                    0,
                    "{ctx}: both lanes drained"
                );
                if max_batch == 1 {
                    // Every cut is a singleton fill cut by construction.
                    assert_eq!(st.cuts_fill, nq as u64, "{ctx}: singleton fills");
                    assert_eq!(st.cuts_deadline, 0, "{ctx}: no deadline cuts at cap 1");
                    assert_eq!(st.cuts_aged, 0, "{ctx}: no aged cuts at cap 1");
                }
            }
        }
    }
}

#[test]
fn resubmission_after_queue_replacement_still_matches() {
    // enable_admission drains and replaces the previous queue; results
    // must stay identical across the swap (the seam later scheduling
    // work will exercise constantly).
    let c = corpus();
    let p = lsh_params(&c.data, 40, 12, 13);
    let reference = build_cluster(&c.data, &p, &ClusterConfig::new(2, 2)).unwrap();
    let want: Vec<QueryResult> = (0..6).map(|i| reference.query(c.queries.point(i)).unwrap()).collect();

    let mut cluster = build_cluster(&c.data, &p, &ClusterConfig::new(2, 2)).unwrap();
    for round in 0..3 {
        cluster
            .orchestrator
            .enable_admission(AdmissionConfig::new(c.data.dim, 4).with_queue_cap(16));
        let tickets: Vec<_> = (0..6)
            .map(|i| {
                cluster
                    .orchestrator
                    .submit(c.queries.point(i), Duration::from_millis(1))
                    .unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_bit_identical(&t.wait().unwrap(), &want[i], &format!("round={round} q={i}"));
        }
    }
}
