//! Streaming-ingest contracts (the live index end to end), all
//! deterministic — MockClock / TickClock only, no sleeps:
//!
//! * **seal equivalence** — an index grown from empty via inserts and
//!   then sealed answers bit-identically (neighbors AND stats) to
//!   `SlshIndex::build_full` over the same points, across seeds and both
//!   LSH-only / stratified configs.
//! * **snapshot consistency** — queries racing a concurrent inserter
//!   never observe torn state: every neighbor is a fully-written point
//!   that was indexed before the query finished, carrying its true
//!   bit-exact distance (the epoch-guarded prefix contract).
//! * **deterministic sealing** — size trips at exactly the policy count;
//!   age trips exactly at the bound on the injected clock.
//! * **budget enforcement across segments** — partial answers stay
//!   monotone prefixes as the budget grows, `Shed`/`PartialResults`
//!   reject-before-work at zero budget, and an unbounded deadline is
//!   bit-identical to the unenforced path — at the index AND node level.
//! * **local/TCP parity** — the same insert stream routed through
//!   in-process live nodes and through `InsertBatch`/`InsertAck` frames
//!   over real sockets yields identical acks and identical query
//!   results.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dslsh::coordinator::admission::{AdmissionConfig, Budget, BudgetPolicy, Class};
use dslsh::coordinator::orchestrator::{NodeHandle, Orchestrator};
use dslsh::engine::native::NativeEngine;
use dslsh::engine::{DistanceEngine, Metric, ScanCancel};
use dslsh::knn::heap::TopK;
use dslsh::knn::predict::VoteConfig;
use dslsh::lsh::family::LayerSpec;
use dslsh::node::node::LocalNode;
use dslsh::slsh::{
    BatchOutput, InnerParams, LiveIndex, LiveScratch, QueryScratch, SealPolicy, SealReason,
    SlshIndex, SlshParams, LIVE_ID_STRIDE,
};
use dslsh::util::clock::{Clock, MockClock, TickClock};

use common::{assert_bit_identical, corpus, lsh_params, native_engines, FAR};

fn mock_clock() -> Arc<MockClock> {
    Arc::new(MockClock::new(0))
}

fn slsh_params(data: &dslsh::data::Dataset, seed: u64) -> SlshParams {
    let (lo, hi) = data.value_range();
    SlshParams {
        outer: LayerSpec::outer_l1(data.dim, 12, 8, lo, hi, seed),
        inner: Some(InnerParams { m: 24, l: 8, alpha: 0.02, seed: seed ^ 0xACED }),
        k: 10,
    }
}

/// The engine's own L1 distance for (query, point `id`) — the oracle the
/// torn-read checks compare against bit-for-bit. The scan kernels use a
/// 4-way-unrolled accumulation, so the scalar `l1_dist` is NOT the right
/// reference; one single-candidate scan through the same kernel is.
fn engine_dist(engine: &NativeEngine, q: &[f32], data: &dslsh::data::Dataset, id: usize) -> f32 {
    let mut t = TopK::new(1);
    engine.scan(Metric::L1, q, &data.points, data.dim, &[id as u32], &data.labels, 0, &mut t);
    t.into_sorted()[0].dist
}

/// Insert `data` into `live` in uneven batches (stresses extent
/// splitting) and return how many segments sealed along the way.
fn stream_in(live: &LiveIndex, data: &dslsh::data::Dataset, batch: usize) -> u64 {
    let mut sealed = 0;
    let mut at = 0usize;
    while at < data.len() {
        let take = batch.min(data.len() - at);
        let s = live.insert_batch(
            &data.points[at * data.dim..(at + take) * data.dim],
            &data.labels[at..at + take],
        );
        sealed += s.sealed_now;
        at += take;
    }
    sealed
}

#[test]
fn seal_equivalence_with_build_full_across_seeds_and_configs() {
    for seed in [3u64, 19] {
        let c = corpus(2500, 20, seed);
        let configs = [lsh_params(&c.data, 24, 12, seed ^ 1), slsh_params(&c.data, seed ^ 2)];
        for (ci, params) in configs.iter().enumerate() {
            let live = LiveIndex::new(params, SealPolicy::by_size(c.data.len()), mock_clock());
            stream_in(&live, &c.data, 311);
            assert_eq!(live.sealed_segments(), 1, "seed={seed} cfg={ci}");
            assert_eq!(live.delta_len(), 0);
            let reference = SlshIndex::build_full(params, &c.data);
            let engine = NativeEngine::new();
            let (mut lscr, mut lout) = (LiveScratch::new(), BatchOutput::new());
            let (mut rscr, mut rout) =
                (QueryScratch::new(c.data.len()), BatchOutput::new());
            // Whole query set in one batch: bit-identical neighbors
            // (exact f32 distances) AND stats (comparisons, probes,
            // bucket kinds, tables).
            let mut flat = Vec::new();
            for i in 0..c.queries.len() {
                flat.extend_from_slice(c.queries.point(i));
            }
            live.query_batch(&engine, &flat, &mut lscr, &mut lout);
            reference.query_batch(
                &engine,
                &flat,
                &c.data.points,
                &c.data.labels,
                0,
                &mut rscr,
                &mut rout,
            );
            assert_eq!(lout.len(), c.queries.len());
            for qi in 0..c.queries.len() {
                assert_eq!(
                    lout.neighbors(qi),
                    rout.neighbors(qi),
                    "seed={seed} cfg={ci} qi={qi}"
                );
                assert_eq!(lout.stats(qi), rout.stats(qi), "seed={seed} cfg={ci} qi={qi}");
            }
        }
    }
}

#[test]
fn seal_triggers_deterministically_by_size_and_age() {
    let c = corpus(1000, 5, 7);
    let params = lsh_params(&c.data, 20, 8, 11);
    // Size: 1000 points through a 256-point policy = 3 seals + 232 delta.
    let live = LiveIndex::new(&params, SealPolicy::by_size(256), mock_clock());
    let sealed = stream_in(&live, &c.data, 100);
    assert_eq!(sealed, 3);
    assert_eq!(live.sealed_segments(), 3);
    assert_eq!(live.delta_len(), 1000 - 3 * 256);
    assert_eq!(live.len(), 1000);
    assert_eq!(live.seal_reasons(), vec![SealReason::Size; 3]);

    // Age: nothing seals a tick before the bound, everything at it.
    let clock = mock_clock();
    let live = LiveIndex::new(
        &params,
        SealPolicy::by_size_or_age(10_000, Duration::from_millis(2)),
        Arc::clone(&clock) as Arc<dyn Clock>,
    );
    live.insert_batch(&c.data.points[..40 * c.data.dim], &c.data.labels[..40]);
    clock.advance(Duration::from_millis(2) - Duration::from_nanos(1));
    assert_eq!(live.maybe_seal(), 0, "one tick early must not seal");
    clock.advance(Duration::from_nanos(1));
    assert_eq!(live.maybe_seal(), 1, "exactly at the bound must seal");
    assert_eq!(live.seal_reasons(), vec![SealReason::Age]);
    // An overdue open extent also closes on the next insert's way in.
    live.insert_batch(&c.data.points[..10 * c.data.dim], &c.data.labels[..10]);
    clock.advance(Duration::from_millis(3));
    let s = live.insert_batch(&c.data.points[..c.data.dim], &c.data.labels[..1]);
    assert_eq!(s.sealed_now, 1);
    assert_eq!(live.seal_reasons(), vec![SealReason::Age, SealReason::Age]);
    assert_eq!(live.delta_len(), 1, "the triggering insert starts the fresh extent");

    // Node level: `poll_seal` runs the same age check for a completely
    // quiet stream and propagates the seal to every core.
    let clock = mock_clock();
    let mut node = LocalNode::spawn_live(
        0,
        0,
        &params,
        2,
        native_engines(2),
        Arc::clone(&clock) as Arc<dyn Clock>,
        SealPolicy::by_size_or_age(10_000, Duration::from_millis(2)),
    );
    node.insert_batch(&c.data.points[..20 * c.data.dim], &c.data.labels[..20]);
    let r = node.poll_seal();
    assert_eq!(r.sealed_now, 0, "not due yet");
    clock.advance(Duration::from_millis(2));
    let r = node.poll_seal();
    assert_eq!((r.sealed_now, r.sealed_total, r.total), (1, 1, 20));
    assert_eq!(node.poll_seal().sealed_now, 0, "nothing left to seal");
}

#[test]
fn snapshot_consistency_under_concurrent_insert_and_query() {
    // A writer streams the corpus in while readers hammer queries. No
    // schedule control, no sleeps: the asserted properties hold under
    // EVERY interleaving — that is the epoch contract.
    let c = corpus(4000, 10, 13);
    let params = lsh_params(&c.data, 20, 8, 17);
    let live = Arc::new(LiveIndex::new(&params, SealPolicy::by_size(512), mock_clock()));
    let data = Arc::new(c.data);
    let queries = Arc::new(c.queries);
    let done = Arc::new(AtomicBool::new(false));
    let writer = {
        let (live, data, done) = (Arc::clone(&live), Arc::clone(&data), Arc::clone(&done));
        std::thread::spawn(move || {
            let mut at = 0usize;
            while at < data.len() {
                let take = 97.min(data.len() - at);
                live.insert_batch(
                    &data.points[at * data.dim..(at + take) * data.dim],
                    &data.labels[at..at + take],
                );
                at += take;
            }
            done.store(true, Ordering::Release);
        })
    };
    let readers: Vec<_> = (0..2)
        .map(|r| {
            let (live, data, queries, done) = (
                Arc::clone(&live),
                Arc::clone(&data),
                Arc::clone(&queries),
                Arc::clone(&done),
            );
            std::thread::spawn(move || {
                let engine = NativeEngine::new();
                let (mut scratch, mut out) = (LiveScratch::new(), BatchOutput::new());
                let mut rounds = 0usize;
                // Keep querying until the writer finishes, then once more
                // against the complete index.
                loop {
                    let finished = done.load(Ordering::Acquire);
                    for qi in 0..queries.len() {
                        let q = queries.point((qi + r) % queries.len());
                        live.query_batch(&engine, q, &mut scratch, &mut out);
                        let visible = live.len() as u64; // read AFTER the query
                        let nbs = out.neighbors(0);
                        for w in nbs.windows(2) {
                            assert!(w[0].dist <= w[1].dist, "unsorted answer");
                            assert_ne!(w[0].id, w[1].id, "duplicate neighbor");
                        }
                        for n in nbs {
                            // Every neighbor must be a point inserted
                            // before the query's epoch, fully written
                            // (bit-exact distance against the source
                            // data), with its true label.
                            assert!(n.id < visible, "neighbor past the epoch: {n:?}");
                            let i = n.id as usize;
                            let true_d = engine_dist(&engine, q, &data, i);
                            assert_eq!(n.dist, true_d, "torn read for point {i}");
                            assert_eq!(n.label, data.labels[i]);
                        }
                    }
                    rounds += 1;
                    if finished {
                        break;
                    }
                }
                rounds
            })
        })
        .collect();
    writer.join().unwrap();
    for r in readers {
        assert!(r.join().unwrap() >= 1);
    }
    // Final state: everything visible and searchable.
    assert_eq!(live.len(), data.len());
    let engine = NativeEngine::new();
    let (mut scratch, mut out) = (LiveScratch::new(), BatchOutput::new());
    live.query_batch(&engine, data.point(777), &mut scratch, &mut out);
    assert!(out.neighbors(0).iter().any(|n| n.id == 777 && n.dist == 0.0));
}

#[test]
fn budget_enforcement_is_monotone_across_segments() {
    // TickClock: every deadline check costs one tick, so coverage is a
    // pure function of the budget — sweep it and demand monotone,
    // prefix-true partials, converging to the unenforced answer.
    let c = corpus(1200, 5, 23);
    let params = lsh_params(&c.data, 20, 8, 29);
    let live = LiveIndex::new(&params, SealPolicy::by_size(300), mock_clock());
    stream_in(&live, &c.data, 150);
    assert_eq!(live.sealed_segments(), 4);
    let engine = NativeEngine::new();
    let q = c.queries.point(0);
    let (mut scratch, mut plain) = (LiveScratch::new(), BatchOutput::new());
    live.query_batch(&engine, q, &mut scratch, &mut plain);
    let full = (plain.stats(0).tables, plain.stats(0).comparisons);
    assert_eq!(full.0, 32, "4 segments × 8 tables");
    let mut out = BatchOutput::new();
    let mut prev = (0u32, 0u64);
    let mut saw_partial_with_work = false;
    for budget_ticks in [0u64, 1, 2, 4, 8, 16, 32, 64, 1 << 40] {
        let cancel =
            ScanCancel::until(Arc::new(TickClock::new(0, 1)), budget_ticks);
        live.query_batch_cancel(&engine, q, &mut scratch, &mut out, &cancel);
        let st = out.stats(0);
        assert!(
            st.tables >= prev.0 && st.comparisons >= prev.1,
            "coverage must grow with budget: {budget_ticks} ticks, \
             ({}, {}) after {prev:?}",
            st.tables,
            st.comparisons
        );
        prev = (st.tables, st.comparisons);
        if budget_ticks == 0 {
            assert!(st.partial);
            assert_eq!(st.comparisons, 0, "zero budget ⇒ zero work");
            assert!(out.neighbors(0).is_empty());
        }
        if st.partial && st.comparisons > 0 {
            saw_partial_with_work = true;
        }
        // Partial or not, every returned neighbor carries its true
        // distance (prefixes, never garbage).
        for n in out.neighbors(0) {
            assert_eq!(n.dist, engine_dist(&engine, q, &c.data, n.id as usize));
        }
        if !st.partial {
            assert_eq!((st.tables, st.comparisons), full, "complete answer = full coverage");
            assert_eq!(out.neighbors(0), plain.neighbors(0));
        }
    }
    assert!(saw_partial_with_work, "sweep never produced a mid-scan partial");
    assert!(!out.stats(0).partial, "the largest budget must complete");
}

#[test]
fn node_level_budget_policies_work_on_live_nodes() {
    let c = corpus(1500, 5, 31);
    let params = lsh_params(&c.data, 24, 12, 37);
    let spawn = |clock: Arc<dyn Clock>| {
        LocalNode::spawn_live(0, 0, &params, 2, native_engines(2), clock, SealPolicy::by_size(400))
    };
    let fill = |node: &mut LocalNode| {
        let d = &c.data;
        let mut at = 0usize;
        while at < d.len() {
            let take = 250.min(d.len() - at);
            node.insert_batch(&d.points[at * d.dim..(at + take) * d.dim], &d.labels[at..at + take]);
            at += take;
        }
    };
    let flat = |n: usize| {
        let mut v = Vec::new();
        for i in 0..n {
            v.extend_from_slice(c.queries.point(i));
        }
        Arc::new(v)
    };

    // Shed with the budget already spent: rejected before ANY scan work.
    let mut node = spawn(mock_clock());
    fill(&mut node);
    let shed_budget = Budget::enforced(0, BudgetPolicy::Shed);
    let replies = node.query_batch_budget(flat(3), 3, shed_budget, Class::Monitor);
    assert_eq!(replies.len(), 3);
    for r in &replies {
        assert!(r.shed && r.partial);
        assert!(r.neighbors.is_empty());
        assert!(r.comparisons.iter().all(|&x| x == 0));
    }

    // PartialResults at zero budget: served, but the deadline trips on
    // the first check — partial answers with zero work.
    let replies = node.query_batch_budget(
        flat(3),
        3,
        Budget::enforced(0, BudgetPolicy::PartialResults),
        Class::Monitor,
    );
    for r in &replies {
        assert!(r.partial && !r.shed);
        assert!(r.comparisons.iter().all(|&x| x == 0));
    }

    // PartialResults with a budget a frozen MockClock can never spend:
    // bit-identical to the unenforced path on a twin node.
    let mut twin = spawn(mock_clock());
    fill(&mut twin);
    let enforced = node.query_batch_budget(
        flat(4),
        4,
        Budget::enforced(FAR.as_micros() as u64, BudgetPolicy::PartialResults),
        Class::Monitor,
    );
    let plain = twin.query_batch(flat(4), 4);
    for (e, p) in enforced.iter().zip(&plain) {
        assert!(!e.partial);
        assert_eq!(e.neighbors, p.neighbors);
        assert_eq!(e.comparisons, p.comparisons);
    }
}

#[test]
fn insert_batch_local_and_tcp_clusters_are_bit_identical() {
    let c = corpus(3000, 15, 41);
    let params = lsh_params(&c.data, 24, 12, 43);
    let policy = SealPolicy::by_size(300);

    // Local live cluster (MockClock: sealing is size-driven anyway).
    let local_nodes: Vec<Box<dyn NodeHandle>> = (0..2)
        .map(|i| {
            Box::new(LocalNode::spawn_live(
                i,
                i as u64 * LIVE_ID_STRIDE,
                &params,
                2,
                native_engines(2),
                mock_clock(),
                policy,
            )) as Box<dyn NodeHandle>
        })
        .collect();
    let local = Orchestrator::start(local_nodes, params.k, VoteConfig::default());

    // TCP live cluster: same topology, inserts/acks cross real sockets.
    let (remote, servers) = common::tcp_live_cluster(&params, 2, 2, policy);

    // Drive both identically: interleave routed insert batches with
    // broadcast queries, comparing acks and answers at every step.
    let d = &c.data;
    let batch = 125usize;
    for b in 0..(d.len() / batch) {
        let at = b * batch;
        let pts = &d.points[at * d.dim..(at + batch) * d.dim];
        let lbs = &d.labels[at..at + batch];
        let lo = local.insert_batch(pts, lbs).unwrap();
        let ro = remote.insert_batch(pts, lbs).unwrap();
        assert_eq!(lo, ro, "insert acks diverged at batch {b}");
        assert_eq!(lo.node, b % 2);
        if b % 5 == 4 {
            let qi = b % c.queries.len();
            let lr = local.query(c.queries.point(qi)).unwrap();
            let rr = remote.query(c.queries.point(qi)).unwrap();
            assert_bit_identical(&lr, &rr, &format!("query after batch {b}"));
        }
    }
    // Ingest telemetry matched the stream on both sides.
    let (li, ri) = (local.ingest_stats(), remote.ingest_stats());
    assert_eq!(li, ri);
    assert_eq!(li.points, d.len() as u64);
    assert_eq!(li.sealed_segments, 2 * (d.len() as u64 / 2 / 300));
    // Full query sweep over the final index.
    for qi in 0..c.queries.len() {
        let lr = local.query(c.queries.point(qi)).unwrap();
        let rr = remote.query(c.queries.point(qi)).unwrap();
        assert_bit_identical(&lr, &rr, &format!("final query {qi}"));
        assert!(!lr.partial);
    }
    drop(remote);
    for s in servers {
        s.join().unwrap();
    }
}

#[test]
fn per_lane_ingest_counters_surface_next_to_partials() {
    let c = corpus(400, 2, 47);
    let params = lsh_params(&c.data, 16, 8, 53);
    let nodes: Vec<Box<dyn NodeHandle>> = vec![Box::new(LocalNode::spawn_live(
        0,
        0,
        &params,
        1,
        native_engines(1),
        mock_clock(),
        SealPolicy::by_size(1000),
    ))];
    let mut orch = Orchestrator::start(nodes, params.k, VoteConfig::default());
    orch.enable_admission(AdmissionConfig::new(c.data.dim, 4));
    let d = &c.data;
    orch.insert_batch_class(&d.points[..100 * d.dim], &d.labels[..100], Class::Monitor).unwrap();
    orch.insert_batch_class(
        &d.points[100 * d.dim..130 * d.dim],
        &d.labels[100..130],
        Class::Analytics,
    )
    .unwrap();
    orch.insert_batch(&d.points[130 * d.dim..135 * d.dim], &d.labels[130..135]).unwrap();
    let stats = orch.admission().unwrap().stats();
    assert_eq!(stats.monitor.inserted, 105, "default class is Monitor");
    assert_eq!(stats.analytics.inserted, 30);
    let ing = orch.ingest_stats();
    assert_eq!(ing.batches, 3);
    assert_eq!(ing.points, 135);
}
