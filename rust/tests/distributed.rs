//! Distributed-runtime integration tests: in-process cluster vs TCP
//! loopback cluster vs single-node ground truth.
//!
//! Every listener here binds port 0 and propagates the kernel-chosen
//! port to the client side, so the suite is parallel-safe (tier-1 runs
//! tests concurrently; a fixed port would flake on collision).

// The positional submit/query entry points are deprecated shims over the
// QuerySpec API; this file exercises them on purpose (they must keep
// working bit-identically until removal).
#![allow(deprecated)]

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::{corpus as make_corpus, lsh_params, tcp_cluster};
use dslsh::coordinator::admission::completion_slot;
use dslsh::coordinator::orchestrator::NodeHandle;
use dslsh::coordinator::{build_cluster, AdmissionConfig, Class, ClusterConfig};
use dslsh::data::Corpus;
use dslsh::engine::native::NativeEngine;
use dslsh::engine::{DistanceEngine, Metric};
use dslsh::knn::exhaustive::pknn_query;
use dslsh::lsh::family::LayerSpec;
use dslsh::node::node::LocalNode;
use dslsh::slsh::SlshParams;

fn corpus() -> Corpus {
    make_corpus(5000, 60, 77)
}

fn params(data: &dslsh::data::Dataset) -> SlshParams {
    lsh_params(data, 40, 16, 13)
}

#[test]
fn tcp_cluster_matches_local_cluster() {
    let c = corpus();
    let p = params(&c.data);
    let nu = 2;
    let cores = 2;

    // Local (in-process) cluster vs TCP loopback cluster (one port-0
    // server thread per node; see tests/common/mod.rs).
    let local = build_cluster(&c.data, &p, &ClusterConfig::new(nu, cores)).unwrap();
    let (tcp, servers) = tcp_cluster(&c.data, &p, nu, cores);

    for i in 0..25 {
        let q = c.queries.point(i);
        let a = local.query(q).unwrap();
        let b = tcp.query(q).unwrap();
        assert_eq!(a.prediction, b.prediction, "query {i}");
        assert_eq!(a.max_comparisons, b.max_comparisons, "query {i}");
        assert_eq!(
            a.neighbors.iter().map(|n| n.id).collect::<Vec<_>>(),
            b.neighbors.iter().map(|n| n.id).collect::<Vec<_>>(),
            "query {i}"
        );
    }
    drop(tcp);
    for s in servers {
        let served = s.join().unwrap();
        assert_eq!(served, 25);
    }
}

#[test]
fn tcp_admission_with_budget_frames_matches_local_sequential() {
    // End-to-end over the wire: concurrent submitters -> admission cutter
    // -> `QueryBatchBudget` frames -> remote nodes -> reduction. Results
    // must be identical to sequential queries on an in-process cluster
    // with the same spec, and the servers must account every query.
    let c = corpus();
    let p = params(&c.data);
    let nu = 2;
    let cores = 2;
    let n_queries = 16usize;

    let local = build_cluster(&c.data, &p, &ClusterConfig::new(nu, cores)).unwrap();

    let (mut tcp, servers) = tcp_cluster(&c.data, &p, nu, cores);
    tcp.enable_admission(AdmissionConfig::new(c.data.dim, 4).with_queue_cap(32));
    let orch = &tcp;

    // Two concurrent submitters with a finite budget: every cut travels
    // as a QueryBatchBudget frame (budget != NO_BUDGET). One submitter
    // rides the monitor lane, the other the analytics lane, so the class
    // byte crosses the wire in both values (and mixed cuts resolve to
    // the monitor class).
    let results: Vec<(usize, dslsh::coordinator::QueryResult)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|t| {
                let c = &c;
                let class = if t == 0 { Class::Monitor } else { Class::Analytics };
                s.spawn(move || {
                    (t..n_queries)
                        .step_by(2)
                        .map(|i| {
                            let ticket = orch
                                .submit_class(
                                    c.queries.point(i),
                                    Duration::from_millis(1),
                                    class,
                                )
                                .unwrap();
                            (i, ticket.wait().unwrap())
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(results.len(), n_queries);
    for (i, b) in &results {
        let a = local.query(c.queries.point(*i)).unwrap();
        assert_eq!(a.prediction, b.prediction, "query {i}");
        assert_eq!(a.max_comparisons, b.max_comparisons, "query {i}");
        assert_eq!(
            a.neighbors.iter().map(|n| n.id).collect::<Vec<_>>(),
            b.neighbors.iter().map(|n| n.id).collect::<Vec<_>>(),
            "query {i}"
        );
    }
    drop(tcp);
    for s in servers {
        let served = s.join().unwrap();
        assert_eq!(served, n_queries as u64, "server must account every budget-batch query");
    }
}

#[test]
fn completion_slot_handoff_stress_across_threads() {
    // Loom-style schedule exploration with plain threads: 100 iterations
    // of the one-shot reply-path handoff under three racing schedules —
    // producer-first, consumer-first (forced park), and a genuine race.
    for round in 0..100u32 {
        // Producer wins: value is published before the reader looks.
        let (w, r) = completion_slot();
        w.fulfill(round);
        assert_eq!(r.wait(), Some(round));

        // Consumer parks first (it spawns, the producer yields to give it
        // a chance to register its waiter), then the value arrives.
        let (w, r) = completion_slot();
        let consumer = std::thread::spawn(move || r.wait());
        std::thread::yield_now();
        w.fulfill(round + 1000);
        assert_eq!(consumer.join().unwrap(), Some(round + 1000));

        // Free-for-all: both sides race from a standing start.
        let (w, r) = completion_slot();
        let producer = std::thread::spawn(move || w.fulfill(round + 2000));
        let consumer = std::thread::spawn(move || r.wait());
        producer.join().unwrap();
        assert_eq!(consumer.join().unwrap(), Some(round + 2000));
    }
}

#[test]
fn distributed_knn_equals_single_node_knn() {
    // Orchestrator K-NN over ν shards == single node over the whole set.
    let c = corpus();
    let p = params(&c.data);
    let single = build_cluster(&c.data, &p, &ClusterConfig::new(1, 1)).unwrap();
    let multi = build_cluster(&c.data, &p, &ClusterConfig::new(4, 2)).unwrap();
    for i in 0..20 {
        let q = c.queries.point(i);
        let a = single.query(q).unwrap();
        let b = multi.query(q).unwrap();
        assert_eq!(
            a.neighbors.iter().map(|n| n.id).collect::<Vec<_>>(),
            b.neighbors.iter().map(|n| n.id).collect::<Vec<_>>(),
            "query {i}"
        );
        assert!((a.positive_share - b.positive_share).abs() < 1e-9);
    }
}

#[test]
fn lsh_recall_and_comparisons_vs_pknn() {
    // The whole point of the paper: far fewer comparisons than PKNN at
    // acceptable K-NN recall.
    let c = corpus();
    // Tighter keys than the shared fixture: at this small n the PKNN
    // per-processor share is only n/8, so m must be large enough for
    // bucket selectivity to beat it (at paper scale any m in the grid
    // does; see the scaling benches).
    let (lo, hi) = c.data.value_range();
    let p = SlshParams::lsh_only(LayerSpec::outer_l1(c.data.dim, 72, 24, lo, hi, 13), 10);
    let cluster = build_cluster(&c.data, &p, &ClusterConfig::new(2, 4)).unwrap();
    let engine = NativeEngine::new();
    let procs = 8;
    let mut recall_hits = 0usize;
    let mut recall_total = 0usize;
    let mut slsh_comp = Vec::new();
    for i in 0..40 {
        let q = c.queries.point(i);
        let r = cluster.query(q).unwrap();
        slsh_comp.push(r.max_comparisons);
        let truth = pknn_query(
            &engine,
            Metric::L1,
            q,
            &c.data.points,
            c.data.dim,
            &c.data.labels,
            10,
            procs,
        );
        let truth_ids: std::collections::HashSet<u64> =
            truth.neighbors.iter().map(|n| n.id).collect();
        recall_hits += r.neighbors.iter().filter(|n| truth_ids.contains(&n.id)).count();
        recall_total += truth.neighbors.len();
    }
    let recall = recall_hits as f64 / recall_total as f64;
    let pknn_per_proc = (c.data.len() as u64).div_ceil(procs as u64);
    let median_slsh = {
        let mut v = slsh_comp.clone();
        v.sort_unstable();
        v[v.len() / 2]
    };
    assert!(recall > 0.5, "recall={recall}");
    assert!(
        median_slsh < pknn_per_proc,
        "LSH ({median_slsh}) must beat PKNN ({pknn_per_proc}) in comparisons"
    );
}

#[test]
fn node_handle_trait_object_works_for_local_nodes() {
    let c = corpus();
    let p = params(&c.data);
    let shard = Arc::new(c.data.shard(0..2000));
    let engines: Vec<Box<dyn DistanceEngine>> =
        (0..2).map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>).collect();
    let node = LocalNode::spawn(0, shard, 0, &p, 2, engines);
    let mut boxed: Box<dyn NodeHandle> = Box::new(node);
    let reply = boxed.query(c.queries.point(0)).unwrap();
    assert!(reply.neighbors.len() <= 10);
}
