//! Prediction-quality metrics: confusion matrix, MCC (the paper's quality
//! measure — "a robust measure in cases of severe class imbalance"),
//! plus the standard derived rates for completeness.

/// Binary confusion matrix.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub tn: u64,
    pub fp: u64,
    pub fn_: u64,
}

impl Confusion {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate one (prediction, truth) pair.
    pub fn push(&mut self, predicted: bool, actual: bool) {
        match (predicted, actual) {
            (true, true) => self.tp += 1,
            (false, false) => self.tn += 1,
            (true, false) => self.fp += 1,
            (false, true) => self.fn_ += 1,
        }
    }

    pub fn from_pairs(pairs: impl IntoIterator<Item = (bool, bool)>) -> Self {
        let mut c = Self::new();
        for (p, a) in pairs {
            c.push(p, a);
        }
        c
    }

    pub fn total(&self) -> u64 {
        self.tp + self.tn + self.fp + self.fn_
    }

    /// Matthews Correlation Coefficient in [−1, 1]. Degenerate
    /// denominators (a row or column of zeros) return 0, the standard
    /// convention.
    pub fn mcc(&self) -> f64 {
        let (tp, tn, fp, fn_) = (self.tp as f64, self.tn as f64, self.fp as f64, self.fn_ as f64);
        let denom = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
        if denom == 0.0 {
            return 0.0;
        }
        (tp * tn - fp * fn_) / denom
    }

    pub fn accuracy(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / self.total() as f64
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    /// Recall / sensitivity / TPR.
    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }

    pub fn specificity(&self) -> f64 {
        if self.tn + self.fp == 0 {
            return 0.0;
        }
        self.tn as f64 / (self.tn + self.fp) as f64
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 {
            return 0.0;
        }
        2.0 * p * r / (p + r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn perfect_and_inverted_predictors() {
        let perfect = Confusion { tp: 10, tn: 90, fp: 0, fn_: 0 };
        assert!((perfect.mcc() - 1.0).abs() < 1e-12);
        let inverted = Confusion { tp: 0, tn: 0, fp: 90, fn_: 10 };
        assert!((inverted.mcc() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn known_value_cross_check() {
        // sklearn: matthews_corrcoef for tp=6, tn=3, fp=1, fn=2 = 0.47809...
        let c = Confusion { tp: 6, tn: 3, fp: 1, fn_: 2 };
        assert!((c.mcc() - 0.478_091).abs() < 1e-5, "mcc={}", c.mcc());
    }

    #[test]
    fn degenerate_cases_are_zero() {
        assert_eq!(Confusion { tp: 0, tn: 100, fp: 0, fn_: 0 }.mcc(), 0.0);
        assert_eq!(Confusion::new().mcc(), 0.0);
        assert_eq!(Confusion { tp: 5, tn: 0, fp: 0, fn_: 0 }.mcc(), 0.0);
    }

    #[test]
    fn random_predictor_mcc_near_zero_under_imbalance() {
        // 97% negative base rate, predictions independent of truth.
        let mut rng = Xoshiro256::seed_from_u64(1);
        let pairs = (0..200_000).map(|_| (rng.gen_bool(0.03), rng.gen_bool(0.03)));
        let c = Confusion::from_pairs(pairs);
        assert!(c.mcc().abs() < 0.02, "mcc={}", c.mcc());
        // Accuracy is deceptively high — exactly why the paper uses MCC.
        assert!(c.accuracy() > 0.9);
    }

    #[test]
    fn mcc_bounded() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        for _ in 0..2000 {
            let c = Confusion {
                tp: rng.gen_below(50),
                tn: rng.gen_below(50),
                fp: rng.gen_below(50),
                fn_: rng.gen_below(50),
            };
            let m = c.mcc();
            assert!((-1.0..=1.0).contains(&m), "{c:?} -> {m}");
        }
    }

    #[test]
    fn derived_rates() {
        let c = Confusion { tp: 8, tn: 80, fp: 2, fn_: 10 };
        assert!((c.precision() - 0.8).abs() < 1e-12);
        assert!((c.recall() - 8.0 / 18.0).abs() < 1e-12);
        assert!((c.specificity() - 80.0 / 82.0).abs() < 1e-12);
        assert!((c.accuracy() - 0.88).abs() < 1e-12);
        let f1 = c.f1();
        assert!((f1 - (2.0 * 0.8 * (8.0 / 18.0)) / (0.8 + 8.0 / 18.0)).abs() < 1e-12);
    }

    #[test]
    fn push_and_from_pairs_agree() {
        let pairs = [(true, true), (false, true), (true, false), (false, false)];
        let a = Confusion::from_pairs(pairs.iter().copied());
        let mut b = Confusion::new();
        for (p, t) in pairs {
            b.push(p, t);
        }
        assert_eq!(a, b);
        assert_eq!(a.total(), 4);
    }
}
