//! Tables 2 and 3: strong scaling of DSLSH vs PKNN (paper §4.2).
//!
//! Fixed SLSH configuration at a ~10–11% tolerated MCC loss; p = 8 cores
//! per node, ν ∈ {1..5} nodes ⇒ pν ∈ {8, 16, 24, 32, 40} total
//! processors. Reported per pν: median (95% CI) of the maximum number of
//! comparisons across all processors over the query set, PKNN's
//! deterministic n/(pν) share, their ratio, and S₈ (speedup relative to
//! the single-node pν = 8 run).

use anyhow::Result;

use crate::coordinator::{build_cluster, ClusterConfig, EngineKind};
use crate::data::WindowSpec;
use crate::experiments::harness::{cached_corpus, eval_cluster, eval_pknn, outer_params, Scale};
use crate::experiments::report::{fmt_f, fmt_k, Table};
use crate::knn::predict::VoteConfig;
use crate::util::stats::Interval;

/// Which of the two scaling tables to reproduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingTable {
    /// Table 2: AHE-301-30c, tolerated MCC loss 11%.
    Table2,
    /// Table 3: AHE-51-5c, tolerated MCC loss 10%.
    Table3,
}

pub struct ScalingOptions {
    pub scale: Scale,
    pub seed: u64,
    pub engine: EngineKind,
    /// Cores per node (paper: 8).
    pub p: usize,
    /// Node counts to sweep (paper: 1..=5).
    pub nus: Vec<usize>,
    pub k: usize,
    /// Outer LSH configuration (paper-level defaults: the ≤10–11% MCC
    /// loss operating point m_out = 125, L_out = 120).
    pub m: usize,
    pub l: usize,
}

impl ScalingOptions {
    /// Paper-style defaults for one table: fixed configuration at the
    /// dataset's ≤10–11% tolerated-MCC-loss operating point, selected (as
    /// in the paper, §4.2) from the Figure-3-style sweep on that dataset:
    /// AHE-301-30c → (m=125, L=120); AHE-51-5c → (m=200, L=96). The
    /// noisier 10-second subwindows of AHE-51-5c need tighter keys for
    /// bucket selectivity.
    pub fn for_table(which: ScalingTable, scale: Scale, seed: u64) -> Self {
        let (m, l) = match which {
            ScalingTable::Table2 => (125, 120),
            ScalingTable::Table3 => (200, 96),
        };
        Self {
            scale,
            seed,
            engine: EngineKind::Native,
            p: 8,
            nus: vec![1, 2, 3, 4, 5],
            k: 10,
            m,
            l,
        }
    }

    /// Backward-compatible alias (Table 2 operating point).
    pub fn paper_defaults(scale: Scale, seed: u64) -> Self {
        Self::for_table(ScalingTable::Table2, scale, seed)
    }
}

/// One row of Table 2/3.
#[derive(Debug, Clone)]
pub struct ScalingRow {
    pub pv: usize,
    pub median_comps: f64,
    pub ci: Interval,
    pub s8: f64,
    pub pknn_comps: u64,
    pub ratio: f64,
    pub mcc: f64,
    pub mcc_loss: f64,
}

pub struct ScalingResult {
    pub rows: Vec<ScalingRow>,
    pub pknn_mcc: f64,
    pub n: usize,
    pub table: Table,
}

/// Paper medians (×10³ comparisons) for shape comparison in the report.
pub fn paper_reference(which: ScalingTable) -> (&'static str, [f64; 5], [f64; 5]) {
    match which {
        ScalingTable::Table2 => (
            "Table 2 (AHE-301-30c)",
            [9.58, 5.60, 3.36, 2.47, 2.32],
            [100.23, 50.11, 33.40, 25.05, 20.04],
        ),
        ScalingTable::Table3 => (
            "Table 3 (AHE-51-5c)",
            [7.88, 4.46, 2.42, 2.02, 1.53],
            [171.43, 85.72, 57.14, 42.86, 34.29],
        ),
    }
}

pub fn run(which: ScalingTable, opts: &ScalingOptions) -> Result<ScalingResult> {
    let (spec, n) = match which {
        ScalingTable::Table2 => (WindowSpec::ahe_301_30c(), opts.scale.n_301),
        ScalingTable::Table3 => (WindowSpec::ahe_51_5c(), opts.scale.n_51),
    };
    let corpus = cached_corpus(&spec, n, opts.scale.queries, opts.seed)?;
    let vote = VoteConfig::default();
    let params = outer_params(&corpus.data, opts.m, opts.l, opts.seed ^ 0x5CA1E, opts.k);

    let mut rows = Vec::new();
    let mut s8_base: Option<f64> = None;
    let mut pknn_mcc = 0.0;
    for &nu in &opts.nus {
        let procs = nu * opts.p;
        crate::log_info!("scaling", "{:?}: pν = {procs} (ν = {nu}, p = {})", which, opts.p);
        // PKNN baseline at the same processor count (comparisons are the
        // deterministic equal share; MCC is topology-independent).
        let pknn = eval_pknn(&corpus.data, &corpus.queries, opts.k, procs, &vote);
        pknn_mcc = pknn.mcc;
        let cluster = build_cluster(
            &corpus.data,
            &params,
            &ClusterConfig::new(nu, opts.p).with_engine(opts.engine),
        )?;
        let run = eval_cluster(&cluster, &corpus);
        let s8 = match s8_base {
            None => {
                s8_base = Some(run.median_comps);
                1.0
            }
            Some(base) => base / run.median_comps.max(1.0),
        };
        rows.push(ScalingRow {
            pv: procs,
            median_comps: run.median_comps,
            ci: run.ci,
            s8,
            pknn_comps: pknn.comps_per_proc,
            ratio: pknn.comps_per_proc as f64 / run.median_comps.max(1.0),
            mcc: run.mcc,
            mcc_loss: pknn.mcc - run.mcc,
        });
    }

    let (title, paper_dslsh, paper_pknn) = paper_reference(which);
    let mut table = Table::new(
        format!("{title} — strong scaling, n = {} (median #comparisons ×10³)", corpus.data.len()),
        &[
            "pν",
            "DSLSH (S8)",
            "DSLSH CI",
            "PKNN",
            "PKNN/DSLSH",
            "MCC loss",
            "paper DSLSH",
            "paper PKNN",
        ],
    );
    for (i, r) in rows.iter().enumerate() {
        table.row(vec![
            r.pv.to_string(),
            format!("{} ({:.2})", fmt_k(r.median_comps), r.s8),
            format!("[{}, {}]", fmt_k(r.ci.lo), fmt_k(r.ci.hi)),
            fmt_k(r.pknn_comps as f64),
            fmt_f(r.ratio, 2),
            fmt_f(r.mcc_loss, 3),
            paper_dslsh.get(i).map(|v| format!("{v:.2}")).unwrap_or_default(),
            paper_pknn.get(i).map(|v| format!("{v:.2}")).unwrap_or_default(),
        ]);
    }
    Ok(ScalingResult { rows, pknn_mcc, n: corpus.data.len(), table })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_smoke_table3() {
        let dir = std::env::temp_dir().join("dslsh_scaling_cache");
        std::env::set_var("DSLSH_CACHE", &dir);
        let opts = ScalingOptions {
            scale: Scale { n_301: 4000, n_51: 4000, queries: 30 },
            seed: 3,
            engine: EngineKind::Native,
            p: 2,
            nus: vec![1, 2, 4],
            k: 10,
            m: 60,
            l: 24,
        };
        let r = run(ScalingTable::Table3, &opts).unwrap();
        assert_eq!(r.rows.len(), 3);
        // PKNN share halves from pν=2 to pν=4 ... n/(pν) exactly.
        assert_eq!(r.rows[0].pknn_comps, 2000);
        assert_eq!(r.rows[1].pknn_comps, 1000);
        assert_eq!(r.rows[2].pknn_comps, 500);
        // S8 (here S2) must increase with more nodes.
        assert!(r.rows[2].s8 > r.rows[0].s8);
        // Median comparisons must decrease with more nodes.
        assert!(r.rows[2].median_comps < r.rows[0].median_comps);
        std::env::remove_var("DSLSH_CACHE");
        std::fs::remove_dir_all(&dir).ok();
    }
}
