//! Regeneration of every table and figure in the paper's evaluation
//! (§4): Table 1 (datasets), Figures 3–4 (speed vs MCC trade-off),
//! Tables 2–3 (strong scaling), plus the shared harness and reporting.

pub mod harness;
pub mod report;
pub mod scaling;
pub mod table1;
pub mod tradeoff;

pub use harness::{
    cached_corpus, eval_cluster, eval_cluster_batched, eval_pknn, outer_params, EvalRun, Scale,
    EVAL_BATCH,
};
pub use report::Table;
pub use scaling::{ScalingOptions, ScalingTable};
pub use tradeoff::TradeoffOptions;
