//! Shared experiment machinery: corpus caching, cluster evaluation, the
//! PKNN baseline, and the speed/quality measurements every table and
//! figure of the paper is built from.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::{build_cluster, Cluster, ClusterConfig};
use crate::data::{build_corpus, Corpus, CorpusConfig, Dataset, WindowSpec};
use crate::engine::native::NativeEngine;
use crate::engine::Metric;
use crate::knn::exhaustive::pknn_query_batch;
use crate::knn::predict::{positive_share, VoteConfig};
use crate::metrics::Confusion;
use crate::slsh::SlshParams;
use crate::util::stats::{self, Interval};

/// Queries admitted per batch by the batched evaluation paths. Results
/// are identical to per-query evaluation (the batched pipeline is
/// bit-identical); only wall-clock changes.
pub const EVAL_BATCH: usize = 32;

/// Scale presets. The paper's datasets are 0.8M / 1.37M points; defaults
/// run at 1/8 scale so the full suite finishes in minutes on one core
/// (`--full` for paper scale — see DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct Scale {
    pub n_301: usize,
    pub n_51: usize,
    pub queries: usize,
}

impl Scale {
    pub fn default_scale() -> Self {
        Self { n_301: 100_000, n_51: 171_000, queries: 1000 }
    }

    pub fn full() -> Self {
        // Paper: n = 801,725 / 1,371,479, 2000 out-of-sample queries.
        Self { n_301: 801_725, n_51: 1_371_479, queries: 2000 }
    }

    pub fn smoke() -> Self {
        Self { n_301: 12_000, n_51: 16_000, queries: 150 }
    }

    /// Scale selection for the bench binaries: `DSLSH_BENCH_SCALE` ∈
    /// {smoke, default, full} (default: default).
    pub fn from_env() -> Self {
        match std::env::var("DSLSH_BENCH_SCALE").as_deref() {
            Ok("full") => Scale::full(),
            Ok("smoke") => Scale::smoke(),
            _ => Scale::default_scale(),
        }
    }
}

/// Seed for the bench binaries: `DSLSH_SEED` (default 42).
pub fn seed_from_env() -> u64 {
    std::env::var("DSLSH_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

/// Disk-cached corpus generation (dataset builds are the most expensive
/// part of the suite; every experiment shares the same cached corpora).
pub fn cached_corpus(spec: &WindowSpec, n: usize, nq: usize, seed: u64) -> Result<Corpus> {
    let dir = cache_dir();
    std::fs::create_dir_all(&dir).context("creating cache dir")?;
    let stem = format!("{}-g6-n{}-q{}-s{}", spec.name, n, nq, seed);
    let data_path = dir.join(format!("{stem}.data"));
    let query_path = dir.join(format!("{stem}.queries"));
    if data_path.exists() && query_path.exists() {
        let data = Dataset::load(&data_path)?;
        let queries = Dataset::load(&query_path)?;
        if data.len() == n && queries.len() == nq {
            return Ok(Corpus { data, queries });
        }
    }
    crate::log_info!("harness", "generating corpus {stem} (not cached)");
    let corpus = build_corpus(&CorpusConfig::new(spec.clone(), n, nq, seed));
    corpus.data.save(&data_path)?;
    corpus.queries.save(&query_path)?;
    Ok(corpus)
}

fn cache_dir() -> PathBuf {
    std::env::var("DSLSH_CACHE").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("data_cache"))
}

/// Measurements from running the query set through a cluster.
#[derive(Debug, Clone)]
pub struct EvalRun {
    /// Max comparisons across processors, one entry per query.
    pub comps: Vec<f64>,
    pub confusion: Confusion,
    pub mcc: f64,
    pub median_comps: f64,
    pub ci: Interval,
    /// Serving wall-clock divided by the query count (seconds). With the
    /// default batched admission this is an inverse-throughput figure;
    /// run [`eval_cluster_batched`] with batch 1 for the paper's strict
    /// one-in-flight per-query latency.
    pub mean_latency_s: f64,
}

/// Drive every query through the Orchestrator and collect the paper's
/// measurements. Queries are admitted in [`EVAL_BATCH`]-sized blocks so
/// the whole suite rides the batched request path; per-query results
/// (comparisons, predictions, MCC) are identical to sequential
/// admission, and `mean_latency_s` becomes total serving wall-clock over
/// the query count.
pub fn eval_cluster(cluster: &Cluster, corpus: &Corpus) -> EvalRun {
    eval_cluster_batched(cluster, corpus, EVAL_BATCH)
}

/// [`eval_cluster`] with an explicit admission batch size (1 = the
/// paper's strict one-in-flight ICU latency model).
pub fn eval_cluster_batched(cluster: &Cluster, corpus: &Corpus, batch: usize) -> EvalRun {
    let batch = batch.max(1);
    let nq = corpus.queries.len();
    let mut comps = Vec::with_capacity(nq);
    let mut confusion = Confusion::new();
    let mut lat = 0.0;
    let mut start = 0usize;
    while start < nq {
        let end = (start + batch).min(nq);
        // A one-element block through query_batch IS the one-in-flight
        // model: same admission, same latency accounting.
        let qs: Vec<&[f32]> = (start..end).map(|i| corpus.queries.point(i)).collect();
        // The cluster cannot shut down while we hold `&Cluster`, so the
        // only query-path error is unreachable here.
        let rs = cluster.query_batch(&qs).expect("cluster alive for the whole eval");
        debug_assert_eq!(rs.len(), end - start);
        // latency_s of the last result is the whole batch round trip.
        lat += rs.last().map(|r| r.latency_s).unwrap_or(0.0);
        for (j, r) in rs.iter().enumerate() {
            comps.push(r.max_comparisons as f64);
            confusion.push(r.prediction, corpus.queries.labels[start + j]);
        }
        start = end;
    }
    let median_comps = stats::median(&comps);
    let ci = stats::median_ci(&comps, 0.95);
    EvalRun {
        mcc: confusion.mcc(),
        median_comps,
        ci,
        confusion,
        mean_latency_s: lat / nq.max(1) as f64,
        comps,
    }
}

/// PKNN baseline over the same query set: exact K-NN prediction quality
/// and the (deterministic) n/(pν) per-processor comparison count.
pub struct PknnRun {
    pub comps_per_proc: u64,
    pub confusion: Confusion,
    pub mcc: f64,
}

pub fn eval_pknn(data: &Dataset, queries: &Dataset, k: usize, procs: usize, vote: &VoteConfig) -> PknnRun {
    let engine = NativeEngine::new();
    let mut confusion = Confusion::new();
    let mut comps_per_proc = 0u64;
    // Batched exhaustive scans: every shard row is loaded once per query
    // tile instead of once per query. Results are bit-identical to the
    // per-query path.
    let dim = data.dim;
    let nq = queries.len();
    let mut start = 0usize;
    while start < nq {
        let end = (start + EVAL_BATCH).min(nq);
        let block = &queries.points[start * dim..end * dim];
        let results = pknn_query_batch(
            &engine,
            Metric::L1,
            block,
            &data.points,
            dim,
            &data.labels,
            k,
            procs,
        );
        for (j, r) in results.iter().enumerate() {
            comps_per_proc = *r.comparisons.iter().max().unwrap();
            let share = positive_share(&r.neighbors, vote);
            confusion.push(share >= vote.threshold as f64, queries.labels[start + j]);
        }
        start = end;
    }
    PknnRun { comps_per_proc, mcc: confusion.mcc(), confusion }
}

/// One evaluated configuration (a point in Figure 3/4, a row in a table).
#[derive(Debug, Clone)]
pub struct ConfigPoint {
    pub label: String,
    pub m: usize,
    pub l: usize,
    pub inner: Option<(usize, usize)>,
    pub median_comps: f64,
    pub ci: Interval,
    pub mcc: f64,
    pub mcc_loss: f64,
    /// Speedup of median max-comparisons vs PKNN's per-processor share.
    pub speedup: f64,
}

/// Build a cluster for `params`, evaluate it, and relate it to a PKNN
/// reference that was computed once by the caller.
#[allow(clippy::too_many_arguments)]
pub fn eval_config(
    corpus: &Corpus,
    params: &SlshParams,
    cluster_cfg: &ClusterConfig,
    pknn: &PknnRun,
    label: String,
) -> Result<ConfigPoint> {
    let cluster = build_cluster(&corpus.data, params, cluster_cfg)?;
    let run = eval_cluster(&cluster, corpus);
    Ok(ConfigPoint {
        label,
        m: params.outer.m,
        l: params.outer.l,
        inner: params.inner.as_ref().map(|i| (i.m, i.l)),
        speedup: pknn.comps_per_proc as f64 / run.median_comps.max(1.0),
        median_comps: run.median_comps,
        ci: run.ci,
        mcc: run.mcc,
        mcc_loss: pknn.mcc - run.mcc,
    })
}

/// Outer spec helper: the experiment grids always hash over the corpus's
/// global value range with a shared seed (the Root's broadcast).
pub fn outer_params(data: &Dataset, m: usize, l: usize, seed: u64, k: usize) -> SlshParams {
    let (lo, hi) = data.value_range();
    SlshParams::lsh_only(crate::lsh::family::LayerSpec::outer_l1(data.dim, m, l, lo, hi, seed), k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_cache_roundtrip() {
        let dir = std::env::temp_dir().join("dslsh_harness_cache");
        std::env::set_var("DSLSH_CACHE", &dir);
        let spec = WindowSpec::ahe_51_5c();
        let a = cached_corpus(&spec, 1500, 30, 9).unwrap();
        let b = cached_corpus(&spec, 1500, 30, 9).unwrap(); // from disk
        assert_eq!(a.data, b.data);
        assert_eq!(a.queries, b.queries);
        std::env::remove_var("DSLSH_CACHE");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pknn_eval_reports_equal_shares() {
        let spec = WindowSpec::ahe_51_5c();
        let corpus = build_corpus(&CorpusConfig::new(spec, 2000, 20, 3));
        let vote = VoteConfig::default();
        let run = eval_pknn(&corpus.data, &corpus.queries, 10, 8, &vote);
        assert_eq!(run.comps_per_proc, 250);
        assert!(run.mcc >= -1.0 && run.mcc <= 1.0);
    }

    #[test]
    fn eval_config_end_to_end_smoke() {
        let spec = WindowSpec::ahe_51_5c();
        let corpus = build_corpus(&CorpusConfig::new(spec, 3000, 25, 4));
        let vote = VoteConfig::default();
        let pknn = eval_pknn(&corpus.data, &corpus.queries, 10, 4, &vote);
        let params = outer_params(&corpus.data, 48, 12, 7, 10);
        let point = eval_config(
            &corpus,
            &params,
            &ClusterConfig::new(2, 2),
            &pknn,
            "smoke".into(),
        )
        .unwrap();
        assert!(point.median_comps > 0.0);
        assert!(point.ci.lo <= point.median_comps && point.median_comps <= point.ci.hi);
        assert!(point.speedup > 0.0);
        assert!(point.mcc_loss.abs() <= 2.0);
    }
}
