//! Figures 3 and 4: speed vs MCC trade-off on AHE-301-30c with p = 8,
//! ν = 2 (paper §4.1).
//!
//! Figure 3: outer layer only (LSH), m_out ∈ {100..200} × L_out ∈
//! {72, 96, 120}. Figure 4: zoom-in plus the inner layer (SLSH) applied at
//! the *onset* (the best ≤10%-MCC-loss outer point, m_out = 125,
//! L_out = 120): m_in ∈ {40, 65, 90, 115} × L_in ∈ {20, 60}, α = 0.005.
//!
//! Output: one ConfigPoint per grid entry (median speedup over PKNN, 95%
//! CI, MCC and MCC loss) — the data behind the paper's scatter plots —
//! rendered as a table plus an ASCII scatter.

use anyhow::Result;

use crate::coordinator::{ClusterConfig, EngineKind};
use crate::data::WindowSpec;
use crate::experiments::harness::{
    cached_corpus, eval_config, eval_pknn, outer_params, ConfigPoint, Scale,
};
use crate::experiments::report::{fmt_f, Table};
use crate::knn::predict::VoteConfig;
use crate::slsh::params::{fig3_outer_grid, fig4_inner_grid};
use crate::slsh::InnerParams;

pub struct TradeoffOptions {
    pub scale: Scale,
    pub seed: u64,
    pub engine: EngineKind,
    /// ν = 2, p = 8 in the paper.
    pub nu: usize,
    pub p: usize,
    pub k: usize,
    /// Restrict the grid (smoke runs); None = the paper's full grid.
    pub max_configs: Option<usize>,
}

impl TradeoffOptions {
    pub fn paper_defaults(scale: Scale, seed: u64) -> Self {
        Self { scale, seed, engine: EngineKind::Native, nu: 2, p: 8, k: 10, max_configs: None }
    }
}

pub struct TradeoffResult {
    pub points: Vec<ConfigPoint>,
    pub pknn_mcc: f64,
    pub pknn_comps: u64,
    pub table: Table,
    pub scatter: String,
}

/// Figure 3: the outer (LSH-only) grid.
pub fn run_fig3(opts: &TradeoffOptions) -> Result<TradeoffResult> {
    let spec = WindowSpec::ahe_301_30c();
    let corpus = cached_corpus(&spec, opts.scale.n_301, opts.scale.queries, opts.seed)?;
    let vote = VoteConfig::default();
    let procs = opts.nu * opts.p;
    let pknn = eval_pknn(&corpus.data, &corpus.queries, opts.k, procs, &vote);
    let mut grid = fig3_outer_grid();
    if let Some(maxc) = opts.max_configs {
        grid.truncate(maxc);
    }
    let cfg = ClusterConfig::new(opts.nu, opts.p).with_engine(opts.engine);
    let mut points = Vec::new();
    for (m, l) in grid {
        let params = outer_params(&corpus.data, m, l, opts.seed ^ 0xF16_3, opts.k);
        let label = format!("LSH m={m} L={l}");
        crate::log_info!("fig3", "evaluating {label}");
        points.push(eval_config(&corpus, &params, &cfg, &pknn, label)?);
    }
    Ok(render(points, &pknn, "Figure 3 — speedup vs MCC loss (outer LSH grid)"))
}

/// Figure 4: the SLSH inner grid at the onset configuration.
pub fn run_fig4(opts: &TradeoffOptions) -> Result<TradeoffResult> {
    let spec = WindowSpec::ahe_301_30c();
    let corpus = cached_corpus(&spec, opts.scale.n_301, opts.scale.queries, opts.seed)?;
    let vote = VoteConfig::default();
    let procs = opts.nu * opts.p;
    let pknn = eval_pknn(&corpus.data, &corpus.queries, opts.k, procs, &vote);
    let cfg = ClusterConfig::new(opts.nu, opts.p).with_engine(opts.engine);
    let (m_out, l_out) = (125, 120);
    let mut points = Vec::new();
    // The SLSH onset itself (LSH-only reference point).
    let onset = outer_params(&corpus.data, m_out, l_out, opts.seed ^ 0xF16_4, opts.k);
    points.push(eval_config(&corpus, &onset, &cfg, &pknn, "SLSH onset (LSH only)".into())?);
    let mut grid = fig4_inner_grid();
    if let Some(maxc) = opts.max_configs {
        grid.truncate(maxc.saturating_sub(1));
    }
    for (m_in, l_in) in grid {
        let mut params = onset.clone();
        params.inner = Some(InnerParams {
            m: m_in,
            l: l_in,
            alpha: 0.005,
            seed: opts.seed ^ 0x5157,
        });
        let label = format!("SLSH m_in={m_in} L_in={l_in}");
        crate::log_info!("fig4", "evaluating {label}");
        points.push(eval_config(&corpus, &params, &cfg, &pknn, label)?);
    }
    Ok(render(points, &pknn, "Figure 4 — SLSH inner layer at the onset (m_out=125, L_out=120)"))
}

fn render(
    points: Vec<ConfigPoint>,
    pknn: &crate::experiments::harness::PknnRun,
    title: &str,
) -> TradeoffResult {
    let mut table = Table::new(
        title,
        &["config", "median comps", "CI", "speedup", "MCC", "MCC loss"],
    );
    for p in &points {
        table.row(vec![
            p.label.clone(),
            fmt_f(p.median_comps, 0),
            format!("[{:.0}, {:.0}]", p.ci.lo, p.ci.hi),
            fmt_f(p.speedup, 2),
            fmt_f(p.mcc, 3),
            fmt_f(p.mcc_loss, 3),
        ]);
    }
    let scatter = ascii_scatter(&points);
    TradeoffResult { pknn_mcc: pknn.mcc, pknn_comps: pknn.comps_per_proc, points, table, scatter }
}

/// Minimal ASCII rendering of the speedup (x, log-ish) vs MCC-loss (y)
/// scatter so the trade-off front is visible in terminal output.
pub fn ascii_scatter(points: &[ConfigPoint]) -> String {
    if points.is_empty() {
        return String::new();
    }
    let (w, h) = (64usize, 16usize);
    let max_speed = points.iter().map(|p| p.speedup).fold(1.0f64, f64::max);
    let max_loss = points.iter().map(|p| p.mcc_loss).fold(0.05f64, f64::max);
    let min_loss = points.iter().map(|p| p.mcc_loss).fold(0.0f64, f64::min);
    let mut grid = vec![vec![' '; w]; h];
    for (i, p) in points.iter().enumerate() {
        let x = ((p.speedup.ln() / max_speed.ln()).clamp(0.0, 1.0) * (w - 1) as f64) as usize;
        let yf = ((p.mcc_loss - min_loss) / (max_loss - min_loss).max(1e-9)).clamp(0.0, 1.0);
        let y = (yf * (h - 1) as f64) as usize;
        let ch = char::from_digit((i % 36) as u32, 36).unwrap_or('*');
        grid[h - 1 - y][x] = ch;
    }
    let mut out = String::new();
    out.push_str(&format!(
        "MCC loss (top={max_loss:.3}) vs speedup (right={max_speed:.1}x, log scale)\n"
    ));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(w));
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_opts() -> TradeoffOptions {
        TradeoffOptions {
            scale: Scale { n_301: 4000, n_51: 4000, queries: 40 },
            seed: 11,
            engine: EngineKind::Native,
            nu: 2,
            p: 2,
            k: 10,
            max_configs: Some(3),
        }
    }

    #[test]
    fn fig3_smoke_produces_points_with_cis() {
        let dir = std::env::temp_dir().join("dslsh_fig3_cache");
        std::env::set_var("DSLSH_CACHE", &dir);
        let r = run_fig3(&smoke_opts()).unwrap();
        assert_eq!(r.points.len(), 3);
        for p in &r.points {
            assert!(p.ci.lo <= p.median_comps && p.median_comps <= p.ci.hi);
            assert!(p.speedup > 0.0);
        }
        assert!(r.table.render().contains("Figure 3"));
        assert!(!r.scatter.is_empty());
        std::env::remove_var("DSLSH_CACHE");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fig4_smoke_includes_onset_and_inner_points() {
        let dir = std::env::temp_dir().join("dslsh_fig4_cache");
        std::env::set_var("DSLSH_CACHE", &dir);
        let r = run_fig4(&smoke_opts()).unwrap();
        assert!(r.points.len() >= 3);
        assert!(r.points[0].inner.is_none(), "first point is the LSH onset");
        assert!(r.points[1].inner.is_some());
        std::env::remove_var("DSLSH_CACHE");
        std::fs::remove_dir_all(&dir).ok();
    }
}
