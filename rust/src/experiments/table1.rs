//! Table 1: employed ABP datasets for AHE prediction — dataset sizes and
//! class imbalance produced by the rolling-window pipeline, alongside the
//! paper's reported values.

use anyhow::Result;

use crate::data::WindowSpec;
use crate::experiments::harness::{cached_corpus, Scale};
use crate::experiments::report::Table;

/// Paper-reported reference values (name, n, %non-AHE).
pub const PAPER_ROWS: [(&str, f64, f64); 2] =
    [("AHE-301-30c", 8.037e5, 98.45), ("AHE-51-5c", 1.373e6, 96.04)];

pub struct Table1Options {
    pub scale: Scale,
    pub seed: u64,
}

pub fn run(opts: &Table1Options) -> Result<Table> {
    let mut table = Table::new(
        "Table 1 — employed ABP datasets (ours vs paper)",
        &["name", "l", "l/d", "c", "n points", "%non-AHE", "paper n", "paper %non-AHE"],
    );
    let configs = [
        (WindowSpec::ahe_301_30c(), opts.scale.n_301, PAPER_ROWS[0]),
        (WindowSpec::ahe_51_5c(), opts.scale.n_51, PAPER_ROWS[1]),
    ];
    for (spec, n, (paper_name, paper_n, paper_neg)) in configs {
        let corpus = cached_corpus(&spec, n, opts.scale.queries, opts.seed)?;
        let stats = crate::data::dataset::stats(&spec, &corpus.data);
        assert_eq!(spec.name, paper_name);
        table.row(vec![
            stats.name.clone(),
            format!("{} min", stats.lag_min),
            if stats.sub_s >= 60.0 {
                format!("{} min", stats.sub_s / 60.0)
            } else {
                format!("{} s", stats.sub_s)
            },
            format!("{} min", stats.cond_min),
            format!("{}", stats.n),
            format!("{:.2}%", stats.pct_negative * 100.0),
            format!("{paper_n:.3e}"),
            format!("{paper_neg:.2}%"),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke_produces_both_rows() {
        let dir = std::env::temp_dir().join("dslsh_table1_cache");
        std::env::set_var("DSLSH_CACHE", &dir);
        let t = run(&Table1Options {
            scale: Scale { n_301: 2000, n_51: 2500, queries: 10 },
            seed: 5,
        })
        .unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "AHE-301-30c");
        assert_eq!(t.rows[1][0], "AHE-51-5c");
        // Class imbalance must be heavy (paper: >= 96%).
        for row in &t.rows {
            let pct: f64 = row[5].trim_end_matches('%').parse().unwrap();
            assert!(pct > 85.0, "imbalance too weak: {pct}");
        }
        std::env::remove_var("DSLSH_CACHE");
        std::fs::remove_dir_all(&dir).ok();
    }
}
