//! Result rendering: aligned ASCII tables (what the benches print), CSV
//! files, and JSON records for EXPERIMENTS.md bookkeeping.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{Json, JsonObj};

/// A printable results table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Self {
            title: title.into(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect();
        out.push_str(&header.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            let line: Vec<String> =
                row.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}", w = w)).collect();
            out.push_str(&line.join("  "));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for row in &self.rows {
            let escaped: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            out.push_str(&escaped.join(","));
            out.push('\n');
        }
        out
    }

    /// Write `<stem>.csv` and `<stem>.json` under `dir`.
    pub fn save(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir).context("creating results dir")?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        let mut obj = JsonObj::new();
        obj.insert("title", Json::Str(self.title.clone()));
        obj.insert(
            "columns",
            Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
        );
        obj.insert(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        std::fs::write(dir.join(format!("{stem}.json")), Json::Obj(obj).to_string_pretty())?;
        Ok(())
    }
}

/// Format helpers shared by the experiment binaries.
pub fn fmt_k(x: f64) -> String {
    format!("{:.2}", x / 1e3)
}

pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["pν", "DSLSH", "ratio"]);
        t.row(vec!["8".into(), "9.58".into(), "10.46".into()]);
        t.row(vec!["16".into(), "5.60".into(), "8.94".into()]);
        t
    }

    #[test]
    fn render_aligns_columns() {
        let r = sample().render();
        assert!(r.contains("== Demo =="));
        // title, header, separator, two data rows.
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        // All data lines have equal display width (alignment); compare
        // char counts, not bytes — headers contain non-ASCII ("pν").
        assert_eq!(lines[3].chars().count(), lines[4].chars().count());
        assert_eq!(lines[1].chars().count(), lines[3].chars().count());
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1,5".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"1,5\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn save_writes_csv_and_json() {
        let dir = std::env::temp_dir().join("dslsh_report_test");
        sample().save(&dir, "demo").unwrap();
        let csv = std::fs::read_to_string(dir.join("demo.csv")).unwrap();
        assert!(csv.starts_with("pν,DSLSH,ratio"));
        let json = std::fs::read_to_string(dir.join("demo.json")).unwrap();
        assert!(Json::parse(&json).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
