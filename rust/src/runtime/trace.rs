//! End-to-end request tracing: trace IDs, per-stage spans, per-lane and
//! per-shard latency histograms, and the slow-query ring buffer.
//!
//! A [`Tracer`] is owned by the orchestrator and shared (via `Arc`) with
//! the admission queue, the per-shard dispatchers, and the serving edge.
//! It has two tiers with very different costs:
//!
//! - **Histograms — always on.** Every query records its queue-wait /
//!   service / end-to-end time into per-lane [`Histogram`]s, and every
//!   shard reply records network and node-scan time into per-shard
//!   histograms. Each record is three relaxed `fetch_add`s; the only
//!   other hot-path cost is the clock reads the stages already take.
//! - **Span collection — opt-in.** When [`Tracer::set_collect`] turns
//!   collection on, each minted trace gets a pending entry that
//!   accumulates named spans ("queue_wait", "service", "shard_net") and
//!   per-node scan spans as the query moves through the cluster. This
//!   tier takes a mutex per stage boundary and is meant for debugging,
//!   not steady-state serving.
//!
//! Completed traces that were slow (e2e over the configurable threshold)
//! or abnormal (partial, shed, or hedged) are moved into a bounded ring
//! buffer dumpable as JSON — the edge serves it at `GET /v1/debug/slow`.
//!
//! All timestamps come from the injectable [`Clock`] the tracer was built
//! with, so span durations are exact (and tests need no sleeps) under
//! [`MockClock`](crate::util::clock::MockClock). Span start offsets are
//! in the recording layer's clock domain; durations are the meaningful
//! quantity when layers run on different clocks.
//!
//! Trace ID 0 is the "untraced" sentinel everywhere (wire frames, node
//! replies, dispatch plumbing); [`Tracer::mint`] never returns it.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::hist::{HistSnapshot, Histogram};
use crate::util::clock::Clock;
use crate::util::json::{Json, JsonObj};

/// Scheduling lanes the per-lane histograms are indexed by. Mirrors
/// [`Class::idx`](crate::coordinator::admission::Class): 0 = monitor,
/// 1 = analytics.
pub const NUM_LANES: usize = 2;

/// Stable lane labels for metrics and JSON, indexed like `Class::idx`.
pub const LANE_NAMES: [&str; NUM_LANES] = ["monitor", "analytics"];

/// One named stage of a trace: where a query spent `dur_ns` starting at
/// `start_ns` (on the recording layer's clock).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    pub stage: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// One node's contribution to a trace: how long the shard's scan took and
/// what it covered, straight from the reply that crossed the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeSpan {
    pub shard: usize,
    pub scan_ns: u64,
    pub comparisons: u64,
    pub tables: u32,
    pub partial: bool,
    pub shed: bool,
}

/// A completed (or in-flight, while pending) trace of one query.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueryTrace {
    pub trace_id: u64,
    /// Lane index (0 = monitor, 1 = analytics), see [`LANE_NAMES`].
    pub lane: usize,
    pub spans: Vec<Span>,
    pub nodes: Vec<NodeSpan>,
    pub partial: bool,
    pub shed: bool,
    pub hedged: bool,
    /// Why this trace landed in the slow ring ("slow", "partial",
    /// "shed", "hedged" — first cause wins).
    pub cause: &'static str,
    pub e2e_us: u64,
}

impl Default for Span {
    fn default() -> Self {
        Span { stage: "", start_ns: 0, dur_ns: 0 }
    }
}

impl QueryTrace {
    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("trace_id", Json::Num(self.trace_id as f64));
        o.insert("lane", Json::Str(LANE_NAMES[self.lane.min(NUM_LANES - 1)].to_string()));
        o.insert("e2e_us", Json::Num(self.e2e_us as f64));
        o.insert("cause", Json::Str(self.cause.to_string()));
        o.insert("partial", Json::Bool(self.partial));
        o.insert("shed", Json::Bool(self.shed));
        o.insert("hedged", Json::Bool(self.hedged));
        o.insert(
            "spans",
            Json::Arr(
                self.spans
                    .iter()
                    .map(|s| {
                        let mut so = JsonObj::new();
                        so.insert("stage", Json::Str(s.stage.to_string()));
                        so.insert("start_ns", Json::Num(s.start_ns as f64));
                        so.insert("dur_ns", Json::Num(s.dur_ns as f64));
                        Json::Obj(so)
                    })
                    .collect(),
            ),
        );
        o.insert(
            "nodes",
            Json::Arr(
                self.nodes
                    .iter()
                    .map(|n| {
                        let mut no = JsonObj::new();
                        no.insert("shard", Json::Num(n.shard as f64));
                        no.insert("scan_ns", Json::Num(n.scan_ns as f64));
                        no.insert("comparisons", Json::Num(n.comparisons as f64));
                        no.insert("tables", Json::Num(n.tables as f64));
                        no.insert("partial", Json::Bool(n.partial));
                        no.insert("shed", Json::Bool(n.shed));
                        Json::Obj(no)
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }
}

/// Per-lane latency histograms (µs), always recorded.
#[derive(Debug, Default)]
struct LaneHists {
    queue_wait_us: Histogram,
    service_us: Histogram,
    e2e_us: Histogram,
}

/// Per-shard latency histograms (µs), always recorded.
#[derive(Debug, Default)]
struct ShardHists {
    net_us: Histogram,
    scan_us: Histogram,
}

/// Snapshot of one lane's distributions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LaneHistStats {
    pub queue_wait_us: HistSnapshot,
    pub service_us: HistSnapshot,
    pub e2e_us: HistSnapshot,
}

/// Snapshot of one shard's distributions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardHistStats {
    pub net_us: HistSnapshot,
    pub scan_us: HistSnapshot,
}

/// See the module docs. Construct with [`Tracer::new`]; share via `Arc`.
pub struct Tracer {
    clock: Arc<dyn Clock>,
    next_id: AtomicU64,
    collect: AtomicBool,
    slow_threshold_us: AtomicU64,
    lanes: [LaneHists; NUM_LANES],
    shards: Vec<ShardHists>,
    pending: Mutex<HashMap<u64, QueryTrace>>,
    ring: Mutex<VecDeque<QueryTrace>>,
    ring_cap: usize,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("next_id", &self.next_id.load(Ordering::Relaxed))
            .field("collect", &self.collect.load(Ordering::Relaxed))
            .field("shards", &self.shards.len())
            .finish()
    }
}

/// Default slow-query threshold: 10 ms end-to-end.
pub const DEFAULT_SLOW_THRESHOLD_US: u64 = 10_000;

/// Default slow-ring capacity.
pub const DEFAULT_RING_CAP: usize = 64;

impl Tracer {
    /// A tracer for a cluster of `num_shards` shards, timestamping on
    /// `clock`. Span collection starts disabled (histograms are always
    /// on).
    pub fn new(clock: Arc<dyn Clock>, num_shards: usize) -> Tracer {
        Tracer {
            clock,
            next_id: AtomicU64::new(1),
            collect: AtomicBool::new(false),
            slow_threshold_us: AtomicU64::new(DEFAULT_SLOW_THRESHOLD_US),
            lanes: Default::default(),
            shards: (0..num_shards.max(1)).map(|_| ShardHists::default()).collect(),
            pending: Mutex::new(HashMap::new()),
            ring: Mutex::new(VecDeque::new()),
            ring_cap: DEFAULT_RING_CAP,
        }
    }

    /// Read the tracer's clock (ns). Stage boundaries use this so spans
    /// and histograms share one time base.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// The clock this tracer timestamps on.
    pub fn clock(&self) -> Arc<dyn Clock> {
        Arc::clone(&self.clock)
    }

    /// Mint a fresh nonzero trace ID. When span collection is on, also
    /// opens a pending trace that spans will accumulate into.
    pub fn mint(&self, lane: usize) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if self.collect.load(Ordering::Relaxed) {
            let mut p = self.pending.lock().unwrap();
            p.insert(
                id,
                QueryTrace { trace_id: id, lane: lane.min(NUM_LANES - 1), ..QueryTrace::default() },
            );
        }
        id
    }

    /// Turn span collection on or off. Histograms are unaffected.
    pub fn set_collect(&self, on: bool) {
        self.collect.store(on, Ordering::Relaxed);
        if !on {
            self.pending.lock().unwrap().clear();
        }
    }

    /// Whether span collection is currently on.
    pub fn collecting(&self) -> bool {
        self.collect.load(Ordering::Relaxed)
    }

    /// Set the e2e threshold (µs) above which a finished trace enters the
    /// slow ring even without partial/shed/hedge flags.
    pub fn set_slow_threshold_us(&self, us: u64) {
        self.slow_threshold_us.store(us, Ordering::Relaxed);
    }

    /// Record one query's lane distributions (µs). Always-on tier.
    pub fn record_lane(&self, lane: usize, queue_wait_us: u64, service_us: u64, e2e_us: u64) {
        let l = &self.lanes[lane.min(NUM_LANES - 1)];
        l.queue_wait_us.record(queue_wait_us);
        l.service_us.record(service_us);
        l.e2e_us.record(e2e_us);
    }

    /// Record one shard reply's network round-trip time (µs).
    pub fn record_shard_net(&self, shard: usize, us: u64) {
        if let Some(s) = self.shards.get(shard) {
            s.net_us.record(us);
        }
    }

    /// Record one shard reply's node-side scan time (µs).
    pub fn record_shard_scan(&self, shard: usize, us: u64) {
        if let Some(s) = self.shards.get(shard) {
            s.scan_us.record(us);
        }
    }

    /// Append a named span to a pending trace. No-op when `trace_id` is 0
    /// or collection is off (or the trace already finished).
    pub fn span(&self, trace_id: u64, stage: &'static str, start_ns: u64, end_ns: u64) {
        if trace_id == 0 || !self.collect.load(Ordering::Relaxed) {
            return;
        }
        let mut p = self.pending.lock().unwrap();
        if let Some(t) = p.get_mut(&trace_id) {
            t.spans.push(Span { stage, start_ns, dur_ns: end_ns.saturating_sub(start_ns) });
        }
    }

    /// Attach one node's scan span to a pending trace.
    pub fn node_span(&self, trace_id: u64, span: NodeSpan) {
        if trace_id == 0 || !self.collect.load(Ordering::Relaxed) {
            return;
        }
        let mut p = self.pending.lock().unwrap();
        if let Some(t) = p.get_mut(&trace_id) {
            t.nodes.push(span);
        }
    }

    /// Mark a pending trace as hedged (the shard dispatcher fired a
    /// second replica because the first was late).
    pub fn note_hedge(&self, trace_id: u64) {
        if trace_id == 0 || !self.collect.load(Ordering::Relaxed) {
            return;
        }
        let mut p = self.pending.lock().unwrap();
        if let Some(t) = p.get_mut(&trace_id) {
            t.hedged = true;
        }
    }

    /// Finish a trace: record its flags and end-to-end time, and move it
    /// into the slow ring when it was slow, partial, shed, or hedged.
    /// Safe to call with `trace_id == 0` (untraced) — only the caller's
    /// histograms (recorded separately) see that query.
    pub fn finish(&self, trace_id: u64, lane: usize, e2e_us: u64, partial: bool, shed: bool) {
        if trace_id == 0 {
            return;
        }
        // Take the pending entry if collection assembled one; otherwise
        // synthesize a span-less record so the ring still names the query.
        let mut t = if self.collect.load(Ordering::Relaxed) {
            self.pending.lock().unwrap().remove(&trace_id)
        } else {
            None
        }
        .unwrap_or(QueryTrace {
            trace_id,
            lane: lane.min(NUM_LANES - 1),
            ..QueryTrace::default()
        });
        t.partial |= partial;
        t.shed |= shed;
        t.e2e_us = e2e_us;
        let slow = e2e_us >= self.slow_threshold_us.load(Ordering::Relaxed);
        t.cause = if slow {
            "slow"
        } else if t.shed {
            "shed"
        } else if t.partial {
            "partial"
        } else if t.hedged {
            "hedged"
        } else {
            return; // Normal fast query: nothing to keep.
        };
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.ring_cap {
            ring.pop_front();
        }
        ring.push_back(t);
    }

    /// Snapshot one lane's distributions (µs).
    pub fn lane_hists(&self, lane: usize) -> LaneHistStats {
        let l = &self.lanes[lane.min(NUM_LANES - 1)];
        LaneHistStats {
            queue_wait_us: l.queue_wait_us.snapshot(),
            service_us: l.service_us.snapshot(),
            e2e_us: l.e2e_us.snapshot(),
        }
    }

    /// Snapshot one shard's distributions (µs).
    pub fn shard_hists(&self, shard: usize) -> ShardHistStats {
        match self.shards.get(shard) {
            Some(s) => {
                ShardHistStats { net_us: s.net_us.snapshot(), scan_us: s.scan_us.snapshot() }
            }
            None => ShardHistStats::default(),
        }
    }

    /// Number of shards the per-shard histograms cover.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Copy the slow-query ring, oldest first.
    pub fn slow_ring(&self) -> Vec<QueryTrace> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// The slow-query ring as a JSON document (`{"slow": [...]}`).
    pub fn slow_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("slow", Json::Arr(self.slow_ring().iter().map(|t| t.to_json()).collect()));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::MockClock;

    fn tracer() -> (Arc<MockClock>, Tracer) {
        let clock = Arc::new(MockClock::new(0));
        let t = Tracer::new(Arc::clone(&clock) as Arc<dyn Clock>, 2);
        (clock, t)
    }

    #[test]
    fn mint_is_sequential_and_nonzero() {
        let (_c, t) = tracer();
        assert_eq!(t.mint(0), 1);
        assert_eq!(t.mint(1), 2);
        assert_eq!(t.mint(0), 3);
    }

    #[test]
    fn spans_collect_only_when_enabled() {
        let (_c, t) = tracer();
        let id = t.mint(0);
        t.span(id, "service", 0, 500);
        t.set_slow_threshold_us(0); // everything is "slow"
        t.finish(id, 0, 1, false, false);
        let ring = t.slow_ring();
        assert_eq!(ring.len(), 1);
        assert!(ring[0].spans.is_empty(), "collection off: no spans kept");

        t.set_collect(true);
        let id2 = t.mint(1);
        t.span(id2, "queue_wait", 100, 350);
        t.span(id2, "service", 350, 1_350);
        t.node_span(
            id2,
            NodeSpan { shard: 1, scan_ns: 900, comparisons: 42, tables: 3, partial: false, shed: false },
        );
        t.finish(id2, 1, 2, false, false);
        let ring = t.slow_ring();
        assert_eq!(ring.len(), 2);
        let tr = &ring[1];
        assert_eq!(tr.trace_id, id2);
        assert_eq!(tr.lane, 1);
        assert_eq!(
            tr.spans,
            vec![
                Span { stage: "queue_wait", start_ns: 100, dur_ns: 250 },
                Span { stage: "service", start_ns: 350, dur_ns: 1_000 },
            ]
        );
        assert_eq!(tr.nodes.len(), 1);
        assert_eq!(tr.nodes[0].comparisons, 42);
    }

    #[test]
    fn ring_keeps_only_flagged_or_slow_traces_and_is_bounded() {
        let (_c, t) = tracer();
        t.set_slow_threshold_us(1_000);
        // Fast and clean: dropped.
        let a = t.mint(0);
        t.finish(a, 0, 10, false, false);
        assert!(t.slow_ring().is_empty());
        // Partial: kept with cause.
        let b = t.mint(0);
        t.finish(b, 0, 10, true, false);
        // Shed outranks partial.
        let c = t.mint(0);
        t.finish(c, 0, 10, true, true);
        // Slow outranks everything.
        let d = t.mint(0);
        t.finish(d, 0, 5_000, false, false);
        let causes: Vec<&str> = t.slow_ring().iter().map(|q| q.cause).collect();
        assert_eq!(causes, vec!["partial", "shed", "slow"]);

        // Bounded: old entries fall off the front.
        for _ in 0..(DEFAULT_RING_CAP + 5) {
            let id = t.mint(1);
            t.finish(id, 1, 10, true, false);
        }
        let ring = t.slow_ring();
        assert_eq!(ring.len(), DEFAULT_RING_CAP);
        assert_eq!(ring.last().unwrap().lane, 1);
    }

    #[test]
    fn hedge_cause_survives_to_the_ring() {
        let (_c, t) = tracer();
        t.set_collect(true);
        let id = t.mint(0);
        t.note_hedge(id);
        t.finish(id, 0, 10, false, false);
        let ring = t.slow_ring();
        assert_eq!(ring.len(), 1);
        assert!(ring[0].hedged);
        assert_eq!(ring[0].cause, "hedged");
    }

    #[test]
    fn lane_and_shard_hists_accumulate() {
        let (_c, t) = tracer();
        t.record_lane(0, 5, 100, 105);
        t.record_lane(0, 7, 200, 207);
        t.record_lane(1, 1000, 1, 1001);
        t.record_shard_net(1, 250);
        t.record_shard_scan(1, 90);
        // Out-of-range shard indices are ignored, not a panic.
        t.record_shard_net(99, 1);

        let l0 = t.lane_hists(0);
        assert_eq!(l0.queue_wait_us.count, 2);
        assert_eq!(l0.queue_wait_us.sum, 12);
        assert_eq!(l0.e2e_us.count, 2);
        let l1 = t.lane_hists(1);
        assert_eq!(l1.queue_wait_us.sum, 1000);
        let s1 = t.shard_hists(1);
        assert_eq!(s1.net_us.count, 1);
        assert_eq!(s1.scan_us.sum, 90);
        assert_eq!(t.shard_hists(0).net_us.count, 0);
        assert_eq!(t.shard_hists(99), ShardHistStats::default());
    }

    #[test]
    fn slow_json_shape() {
        let (_c, t) = tracer();
        t.set_collect(true);
        let id = t.mint(0);
        t.span(id, "service", 10, 20);
        t.finish(id, 0, 10, true, false);
        let j = t.slow_json();
        let arr = j.get("slow").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("cause").and_then(|c| c.as_str()), Some("partial"));
        assert_eq!(arr[0].get("lane").and_then(|c| c.as_str()), Some("monitor"));
        let spans = arr[0].get("spans").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(spans[0].get("stage").and_then(|s| s.as_str()), Some("service"));
        assert_eq!(spans[0].get("dur_ns").and_then(|d| d.as_u64()), Some(10));
    }
}
