//! Thread-safe façade over the PJRT runtime.
//!
//! `PjRtClient` cannot leave its thread, so [`XlaService`] parks an
//! [`XlaRuntime`](crate::runtime::pjrt::XlaRuntime) on a dedicated service
//! thread; workers hold cloneable [`XlaEngine`] handles that gather
//! candidate rows, round-trip them through a channel, and feed the
//! returned distances into their top-K — implementing [`DistanceEngine`]
//! so the SLSH hot path is engine-agnostic.

use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::Result;

use crate::engine::{push_scored, DistanceEngine, Metric};
use crate::knn::heap::TopK;
use crate::runtime::pjrt::XlaRuntime;

enum Request {
    Scan {
        metric: Metric,
        q: Vec<f32>,
        rows: Vec<f32>,
        n: usize,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Owns the service thread. Dropping shuts the thread down.
pub struct XlaService {
    tx: mpsc::Sender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl XlaService {
    /// Spawn the service thread; fails fast if artifacts are missing or
    /// do not compile.
    pub fn start() -> Result<XlaService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let runtime = match XlaRuntime::discover() {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Scan { metric, q, rows, n, reply } => {
                            let _ = reply.send(runtime.scan_rows(metric, &q, &rows, n));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawning xla-service thread");
        ready_rx.recv().expect("xla-service died during startup")?;
        Ok(XlaService { tx, join: Some(join) })
    }

    /// A new engine handle for a worker thread.
    pub fn engine(&self) -> XlaEngine {
        XlaEngine { tx: Mutex::new(self.tx.clone()) }
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Cloneable, `Send + Sync` scan handle implementing [`DistanceEngine`].
pub struct XlaEngine {
    tx: Mutex<mpsc::Sender<Request>>,
}

impl XlaEngine {
    fn scan_remote(&self, metric: Metric, q: &[f32], rows: Vec<f32>, n: usize) -> Vec<f32> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Request::Scan { metric, q: q.to_vec(), rows, n, reply: reply_tx })
                .expect("xla-service gone");
        }
        reply_rx
            .recv()
            .expect("xla-service dropped reply")
            .expect("xla scan failed")
    }
}

impl DistanceEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn scan(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        if ids.is_empty() {
            return 0;
        }
        // Gather candidate rows into a dense buffer for the device.
        let mut rows = Vec::with_capacity(ids.len() * dim);
        for &id in ids {
            rows.extend_from_slice(&data[id as usize * dim..(id as usize + 1) * dim]);
        }
        let dists = self.scan_remote(metric, q, rows, ids.len());
        for (&id, &d) in ids.iter().zip(&dists) {
            push_scored(topk, id_base, id, d, labels);
        }
        ids.len() as u64
    }

    /// Contiguous ranges need no id materialization OR gather: the rows
    /// are sliced straight out of the shard and shipped in ONE service
    /// round trip (the chunked trait default would cost one lock/channel/
    /// dispatch cycle per 256 ids).
    fn scan_range(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        let n = (range.end - range.start) as usize;
        if n == 0 {
            return 0;
        }
        let rows = data[range.start as usize * dim..range.end as usize * dim].to_vec();
        let dists = self.scan_remote(metric, q, rows, n);
        for (i, &d) in dists.iter().enumerate() {
            push_scored(topk, id_base, range.start + i as u32, d, labels);
        }
        n as u64
    }
}
