//! Thread-safe façade over the PJRT runtime, plus the shared serving
//! observability primitives.
//!
//! `PjRtClient` cannot leave its thread, so [`XlaService`] parks an
//! [`XlaRuntime`](crate::runtime::pjrt::XlaRuntime) on a dedicated service
//! thread; workers hold cloneable [`XlaEngine`] handles that gather
//! candidate rows, round-trip them through a channel, and feed the
//! returned distances into their top-K — implementing [`DistanceEngine`]
//! so the SLSH hot path is engine-agnostic.
//!
//! Every queue on the serving path reports through the same lock-free
//! counters defined here: [`QueueStats`] (depth, high-water, throughput,
//! rejections) instruments both this service's request channel and the
//! coordinator's [admission queue](crate::coordinator::admission),
//! [`CutCounters`] records *why* the admission cutter dispatched each
//! batch (fill vs deadline vs aged vs shutdown drain), and
//! [`LaneCounters`] attributes dispatches and budget overruns to each
//! scheduling class (monitor vs analytics) — the paper's
//! latency-over-throughput stance makes that mix the primary health
//! signal for a serving cluster.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::engine::{push_scored, DistanceEngine, Metric};
use crate::knn::heap::TopK;
use crate::runtime::hist::{HistSnapshot, Histogram};
use crate::runtime::pjrt::XlaRuntime;

/// Lock-free gauges + counters for one bounded serving queue. All fields
/// are monotone or a depth gauge, updated with relaxed atomics — readers
/// get a consistent-enough snapshot for dashboards, never a lock on the
/// hot path.
#[derive(Debug, Default)]
pub struct QueueStats {
    depth: AtomicUsize,
    high_water: AtomicUsize,
    enqueued: AtomicU64,
    dequeued: AtomicU64,
    rejected: AtomicU64,
}

impl QueueStats {
    pub fn new() -> QueueStats {
        QueueStats::default()
    }

    /// Record `n` requests entering the queue; returns the new depth.
    pub fn on_enqueue(&self, n: usize) -> usize {
        self.enqueued.fetch_add(n as u64, Ordering::Relaxed);
        let d = self.depth.fetch_add(n, Ordering::Relaxed) + n;
        let mut hw = self.high_water.load(Ordering::Relaxed);
        while d > hw {
            match self.high_water.compare_exchange_weak(
                hw,
                d,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(cur) => hw = cur,
            }
        }
        d
    }

    /// Record `n` requests leaving the queue (taken into a batch).
    pub fn on_dequeue(&self, n: usize) {
        self.dequeued.fetch_add(n as u64, Ordering::Relaxed);
        self.depth.fetch_sub(n, Ordering::Relaxed);
    }

    /// Record one request turned away at admission (backpressure).
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests currently queued.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Maximum depth ever observed.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Total requests ever admitted.
    pub fn enqueued(&self) -> u64 {
        self.enqueued.load(Ordering::Relaxed)
    }

    /// Total requests ever taken into a batch.
    pub fn dequeued(&self) -> u64 {
        self.dequeued.load(Ordering::Relaxed)
    }

    /// Total requests rejected with queue-full backpressure.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }
}

/// Why the admission cutter dispatched each batch. A healthy
/// latency-bound cluster shows a mix: mostly fill cuts under load
/// (batching is amortizing work), deadline cuts when traffic is sparse
/// (lone requests still meet their budget), and the occasional aged cut
/// when sustained monitor traffic would otherwise starve the analytics
/// lane (the anti-starvation bound firing).
#[derive(Debug, Default)]
pub struct CutCounters {
    fill: AtomicU64,
    deadline: AtomicU64,
    aged: AtomicU64,
    drain: AtomicU64,
}

impl CutCounters {
    pub fn new() -> CutCounters {
        CutCounters::default()
    }

    /// Batch reached `max_batch` before any deadline expired.
    pub fn record_fill(&self) {
        self.fill.fetch_add(1, Ordering::Relaxed);
    }

    /// The earliest pending deadline expired with the batch short.
    pub fn record_deadline(&self) {
        self.deadline.fetch_add(1, Ordering::Relaxed);
    }

    /// An analytics request hit the anti-starvation aging bound.
    pub fn record_aged(&self) {
        self.aged.fetch_add(1, Ordering::Relaxed);
    }

    /// Shutdown drained the residue.
    pub fn record_drain(&self) {
        self.drain.fetch_add(1, Ordering::Relaxed);
    }

    pub fn fill(&self) -> u64 {
        self.fill.load(Ordering::Relaxed)
    }

    pub fn deadline(&self) -> u64 {
        self.deadline.load(Ordering::Relaxed)
    }

    pub fn aged(&self) -> u64 {
        self.aged.load(Ordering::Relaxed)
    }

    pub fn drain(&self) -> u64 {
        self.drain.load(Ordering::Relaxed)
    }
}

/// Per-scheduling-lane dispatch accounting for the admission queue: how
/// many requests of one class left through each cut reason, how many
/// were resolved only after their deadline had already passed (overruns —
/// the tail-latency failures the priority lanes exist to prevent), and
/// how many were answered under budget enforcement with a partial scan
/// or an outright node-side shed (the recall the cluster knowingly traded
/// for the deadline). One instance per
/// [`Class`](crate::coordinator::admission::Class); all counters are
/// monotone relaxed atomics, never a lock on the hot path.
#[derive(Debug, Default)]
pub struct LaneCounters {
    fill: AtomicU64,
    deadline: AtomicU64,
    aged: AtomicU64,
    drain: AtomicU64,
    overruns: AtomicU64,
    partials: AtomicU64,
    sheds: AtomicU64,
    inserts: AtomicU64,
}

impl LaneCounters {
    pub fn new() -> LaneCounters {
        LaneCounters::default()
    }

    /// `n` requests of this class dispatched in a fill cut.
    pub fn record_fill(&self, n: u64) {
        self.fill.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` requests of this class dispatched in a deadline cut.
    pub fn record_deadline(&self, n: u64) {
        self.deadline.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` requests of this class dispatched in an aged cut.
    pub fn record_aged(&self, n: u64) {
        self.aged.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` requests of this class dispatched in a shutdown drain cut.
    pub fn record_drain(&self, n: u64) {
        self.drain.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` requests of this class resolved after their deadline passed.
    pub fn record_overruns(&self, n: u64) {
        self.overruns.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` requests of this class answered from an incomplete scan
    /// (budget enforcement returned a partial result).
    pub fn record_partials(&self, n: u64) {
        self.partials.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` requests of this class where at least one node shed the batch
    /// before any scan work (budget already spent on arrival).
    pub fn record_sheds(&self, n: u64) {
        self.sheds.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` points ingested (online inserts) attributed to this class —
    /// live monitor streams vs analytics backfills share the cluster the
    /// same way queries do, so ingest volume is per-lane health signal
    /// too.
    pub fn record_inserts(&self, n: u64) {
        self.inserts.fetch_add(n, Ordering::Relaxed);
    }

    pub fn fill(&self) -> u64 {
        self.fill.load(Ordering::Relaxed)
    }

    pub fn deadline(&self) -> u64 {
        self.deadline.load(Ordering::Relaxed)
    }

    pub fn aged(&self) -> u64 {
        self.aged.load(Ordering::Relaxed)
    }

    pub fn drain(&self) -> u64 {
        self.drain.load(Ordering::Relaxed)
    }

    pub fn overruns(&self) -> u64 {
        self.overruns.load(Ordering::Relaxed)
    }

    pub fn partials(&self) -> u64 {
        self.partials.load(Ordering::Relaxed)
    }

    pub fn sheds(&self) -> u64 {
        self.sheds.load(Ordering::Relaxed)
    }

    pub fn inserts(&self) -> u64 {
        self.inserts.load(Ordering::Relaxed)
    }

    /// Total requests of this class ever dispatched, across all reasons.
    pub fn dispatched(&self) -> u64 {
        self.fill() + self.deadline() + self.aged() + self.drain()
    }
}

/// Cluster-wide online-ingest telemetry: how much the live index grew and
/// how often deltas sealed into immutable segments. Lives beside the
/// queue/cut/lane counters because ingest shares the serving path — a
/// seal is a build burst the latency dashboards need to see next to the
/// partial/shed counts it can cause.
#[derive(Debug, Default)]
pub struct IngestCounters {
    batches: AtomicU64,
    points: AtomicU64,
    sealed_segments: AtomicU64,
}

/// Snapshot of [`IngestCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct IngestStats {
    /// Insert batches routed.
    pub batches: u64,
    /// Points appended across all nodes.
    pub points: u64,
    /// Segments sealed (delta → immutable) across all nodes.
    pub sealed_segments: u64,
}

impl IngestCounters {
    pub fn new() -> IngestCounters {
        IngestCounters::default()
    }

    /// One routed batch of `points` points.
    pub fn record_batch(&self, points: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.points.fetch_add(points, Ordering::Relaxed);
    }

    /// `n` segments sealed as a consequence of an insert (or age poll).
    pub fn record_seals(&self, n: u64) {
        self.sealed_segments.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> IngestStats {
        IngestStats {
            batches: self.batches.load(Ordering::Relaxed),
            points: self.points.load(Ordering::Relaxed),
            sealed_segments: self.sealed_segments.load(Ordering::Relaxed),
        }
    }
}

/// Cluster-wide fault-tolerance telemetry, shared by every shard
/// dispatcher: how often queries were hedged, failed over or degraded to
/// synthesized sheds, and how replica recovery is going. The hedge/shed
/// counters are the dashboard complement of
/// [`QueryResult::shed_nodes`](crate::coordinator::QueryResult) — a
/// rising `synthesized_sheds` means callers are getting partial answers
/// because replicas are dead or slow, not because budgets are tight.
#[derive(Debug, Default)]
pub struct FailoverCounters {
    hedges: AtomicU64,
    hedge_wins: AtomicU64,
    failovers: AtomicU64,
    synthesized_sheds: AtomicU64,
    heartbeats: AtomicU64,
    reconnect_attempts: AtomicU64,
    reconnects: AtomicU64,
    down_transitions: AtomicU64,
    /// Gauge: replicas currently `Down` across all shards — the
    /// cluster-dependency check behind the edge's `/readyz`.
    down_now: AtomicU64,
}

/// Snapshot of [`FailoverCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FailoverStats {
    /// Queries dispatched a second time because the first replica was
    /// late past the hedge delay.
    pub hedges: u64,
    /// Hedged queries won by the hedge replica (the straggler lost).
    pub hedge_wins: u64,
    /// Re-dispatches after a replica failed mid-request.
    pub failovers: u64,
    /// Requests that degraded to a dispatcher-synthesized shed reply
    /// (every replica dead or the request timeout elapsed).
    pub synthesized_sheds: u64,
    /// Heartbeat probes sent.
    pub heartbeats: u64,
    /// Reconnect attempts fired on the backoff schedule.
    pub reconnect_attempts: u64,
    /// Reconnects that succeeded (replica revived to `Suspect`).
    pub reconnects: u64,
    /// `Up`/`Suspect` → `Down` transitions.
    pub down_transitions: u64,
    /// Replicas currently `Down` (gauge, not monotone): zero means every
    /// replica of every shard is reachable — the readiness condition the
    /// serving edge's `/readyz` reports.
    pub replicas_down: u64,
}

impl FailoverCounters {
    pub fn new() -> FailoverCounters {
        FailoverCounters::default()
    }

    pub fn record_hedge(&self) {
        self.hedges.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_hedge_win(&self) {
        self.hedge_wins.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_failover(&self) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_synthesized_shed(&self) {
        self.synthesized_sheds.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_heartbeat(&self) {
        self.heartbeats.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reconnect_attempt(&self) {
        self.reconnect_attempts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reconnect(&self) {
        self.reconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// A replica transitioned `Up`/`Suspect` → `Down`. Bumps both the
    /// monotone transition count and the current-down gauge; callers must
    /// pair it with [`record_down_recovered`](Self::record_down_recovered)
    /// when the replica leaves `Down`.
    pub fn record_down(&self) {
        self.down_transitions.fetch_add(1, Ordering::Relaxed);
        self.down_now.fetch_add(1, Ordering::Relaxed);
    }

    /// A `Down` replica recovered (reconnect succeeded or a late reply
    /// proved it alive). Saturates at zero so an unmatched call can never
    /// wrap the gauge.
    pub fn record_down_recovered(&self) {
        let _ = self
            .down_now
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    pub fn hedges(&self) -> u64 {
        self.hedges.load(Ordering::Relaxed)
    }

    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    pub fn reconnect_attempts(&self) -> u64 {
        self.reconnect_attempts.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> FailoverStats {
        FailoverStats {
            hedges: self.hedges.load(Ordering::Relaxed),
            hedge_wins: self.hedge_wins.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            synthesized_sheds: self.synthesized_sheds.load(Ordering::Relaxed),
            heartbeats: self.heartbeats.load(Ordering::Relaxed),
            reconnect_attempts: self.reconnect_attempts.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            down_transitions: self.down_transitions.load(Ordering::Relaxed),
            replicas_down: self.down_now.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------------
// Serving-edge observability
// ---------------------------------------------------------------------------

/// Which serving-edge endpoint a request hit, for per-endpoint
/// accounting. `Other` collects unknown paths and requests that failed
/// before routing (malformed HTTP never names an endpoint).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeEndpoint {
    /// `POST /v1/query`
    Query,
    /// `POST /v1/insert`
    Insert,
    /// `GET /v1/stats`
    Stats,
    /// `GET /healthz` and `GET /readyz`
    Health,
    /// `GET /metrics` and `GET /v1/debug/slow` (the scrape surface).
    Metrics,
    /// Everything else (404s, parse failures).
    Other,
}

impl EdgeEndpoint {
    pub const ALL: [EdgeEndpoint; 6] = [
        EdgeEndpoint::Query,
        EdgeEndpoint::Insert,
        EdgeEndpoint::Stats,
        EdgeEndpoint::Health,
        EdgeEndpoint::Metrics,
        EdgeEndpoint::Other,
    ];

    fn idx(self) -> usize {
        match self {
            EdgeEndpoint::Query => 0,
            EdgeEndpoint::Insert => 1,
            EdgeEndpoint::Stats => 2,
            EdgeEndpoint::Health => 3,
            EdgeEndpoint::Metrics => 4,
            EdgeEndpoint::Other => 5,
        }
    }

    /// Stable label for stats bodies and dashboards.
    pub fn name(self) -> &'static str {
        match self {
            EdgeEndpoint::Query => "query",
            EdgeEndpoint::Insert => "insert",
            EdgeEndpoint::Stats => "stats",
            EdgeEndpoint::Health => "health",
            EdgeEndpoint::Metrics => "metrics",
            EdgeEndpoint::Other => "other",
        }
    }
}

#[derive(Debug, Default)]
struct EndpointCounters {
    requests: AtomicU64,
    errors: AtomicU64,
    latency_us: Histogram,
}

/// Per-endpoint request/error/latency accounting for the HTTP serving
/// edge ([`crate::net::edge`]) — one row per [`EdgeEndpoint`], all
/// relaxed atomics, same discipline as every other counter block here.
/// Latency is a full [`Histogram`] per endpoint (not just a sum), so the
/// edge can report p50/p99 and `/metrics` can expose the distribution.
#[derive(Debug, Default)]
pub struct EdgeCounters {
    endpoints: [EndpointCounters; 6],
    /// HTTP requests rejected before routing, by parser error code
    /// (satellite of the silently-dropped accounting: 4xxs used to
    /// vanish into `other.errors` with no cause attached).
    http_rejects: CauseCounters,
}

/// Snapshot of one endpoint's counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EndpointStats {
    /// Requests routed to (or failing toward) this endpoint.
    pub requests: u64,
    /// Responses with a 4xx/5xx status.
    pub errors: u64,
    /// Sum of request latencies in µs (divide by `requests` for the
    /// mean; the edge measures on its injected clock). Kept for
    /// compatibility — equals `latency_us.sum`.
    pub latency_us_sum: u64,
    /// Full latency distribution (µs): p50/p99 etc. via
    /// [`HistSnapshot::percentile`].
    pub latency_us: HistSnapshot,
}

/// Snapshot of [`EdgeCounters`], one row per endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EdgeStats {
    pub query: EndpointStats,
    pub insert: EndpointStats,
    pub stats: EndpointStats,
    pub health: EndpointStats,
    pub metrics: EndpointStats,
    pub other: EndpointStats,
}

impl EdgeCounters {
    pub fn new() -> EdgeCounters {
        EdgeCounters::default()
    }

    /// One finished request against `endpoint`: the response status and
    /// the request's wall latency (µs on the edge's clock).
    pub fn record(&self, endpoint: EdgeEndpoint, status: u16, latency_us: u64) {
        let c = &self.endpoints[endpoint.idx()];
        c.requests.fetch_add(1, Ordering::Relaxed);
        if status >= 400 {
            c.errors.fetch_add(1, Ordering::Relaxed);
        }
        c.latency_us.record(latency_us);
    }

    /// One request the HTTP parser rejected with a typed 4xx before it
    /// could be routed, attributed to the parser's stable error `code`
    /// (`"bad-request-line"`, `"body-too-large"`, ...).
    pub fn record_http_reject(&self, code: &'static str) {
        self.http_rejects.note(code);
    }

    /// Per-cause counts of parser-rejected requests, sorted by cause.
    pub fn http_reject_counts(&self) -> Vec<(&'static str, u64)> {
        self.http_rejects.counts()
    }

    fn endpoint(&self, e: EdgeEndpoint) -> EndpointStats {
        let c = &self.endpoints[e.idx()];
        let latency_us = c.latency_us.snapshot();
        EndpointStats {
            requests: c.requests.load(Ordering::Relaxed),
            errors: c.errors.load(Ordering::Relaxed),
            latency_us_sum: latency_us.sum,
            latency_us,
        }
    }

    pub fn snapshot(&self) -> EdgeStats {
        EdgeStats {
            query: self.endpoint(EdgeEndpoint::Query),
            insert: self.endpoint(EdgeEndpoint::Insert),
            stats: self.endpoint(EdgeEndpoint::Stats),
            health: self.endpoint(EdgeEndpoint::Health),
            metrics: self.endpoint(EdgeEndpoint::Metrics),
            other: self.endpoint(EdgeEndpoint::Other),
        }
    }
}

// ---------------------------------------------------------------------------
// Per-cause drop accounting
// ---------------------------------------------------------------------------

/// Counters keyed by a small set of static cause strings. Error paths
/// only (a decode rejection, a parser 4xx) — a mutexed map is fine there
/// and keeps `/metrics` output deterministically ordered.
#[derive(Debug, Default)]
pub struct CauseCounters {
    counts: Mutex<BTreeMap<&'static str, u64>>,
}

impl CauseCounters {
    pub fn new() -> CauseCounters {
        CauseCounters::default()
    }

    /// Count one event attributed to `cause`.
    pub fn note(&self, cause: &'static str) {
        *self.counts.lock().unwrap().entry(cause).or_insert(0) += 1;
    }

    /// All causes seen so far with their counts, sorted by cause name.
    pub fn counts(&self) -> Vec<(&'static str, u64)> {
        self.counts.lock().unwrap().iter().map(|(&k, &v)| (k, v)).collect()
    }
}

/// TCP server-side decode rejections by [`CodecError`] kind
/// (`crate::util::bytes::CodecError::kind`). Process-global because the
/// TCP server loop (`net::tcp::serve_connection`) is a free function with
/// no stats handle — same pattern as the node-side overrun accounting.
static DECODE_REJECTS: Mutex<BTreeMap<&'static str, u64>> = Mutex::new(BTreeMap::new());

/// Count one TCP frame the server rejected at decode, by cause kind.
/// Frames that fail to decode are otherwise invisible: the connection is
/// dropped and no counter anywhere says why.
pub fn note_decode_reject(kind: &'static str) {
    *DECODE_REJECTS.lock().unwrap().entry(kind).or_insert(0) += 1;
}

/// Per-kind counts of TCP decode rejections, sorted by kind.
pub fn decode_reject_counts() -> Vec<(&'static str, u64)> {
    DECODE_REJECTS.lock().unwrap().iter().map(|(&k, &v)| (k, v)).collect()
}

enum Request {
    Scan {
        metric: Metric,
        q: Vec<f32>,
        rows: Vec<f32>,
        n: usize,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Owns the service thread. Dropping shuts the thread down.
pub struct XlaService {
    tx: mpsc::Sender<Request>,
    stats: Arc<QueueStats>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl XlaService {
    /// Spawn the service thread; fails fast if artifacts are missing or
    /// do not compile.
    pub fn start() -> Result<XlaService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(QueueStats::new());
        let stats_svc = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let runtime = match XlaRuntime::discover() {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Scan { metric, q, rows, n, reply } => {
                            stats_svc.on_dequeue(1);
                            let _ = reply.send(runtime.scan_rows(metric, &q, &rows, n));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawning xla-service thread");
        ready_rx.recv().expect("xla-service died during startup")?;
        Ok(XlaService { tx, stats, join: Some(join) })
    }

    /// A new engine handle for a worker thread.
    pub fn engine(&self) -> XlaEngine {
        XlaEngine { tx: Mutex::new(self.tx.clone()), stats: Arc::clone(&self.stats) }
    }

    /// Live depth/throughput counters for the service request channel.
    pub fn queue_stats(&self) -> Arc<QueueStats> {
        Arc::clone(&self.stats)
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Cloneable, `Send + Sync` scan handle implementing [`DistanceEngine`].
pub struct XlaEngine {
    tx: Mutex<mpsc::Sender<Request>>,
    stats: Arc<QueueStats>,
}

impl XlaEngine {
    fn scan_remote(&self, metric: Metric, q: &[f32], rows: Vec<f32>, n: usize) -> Vec<f32> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap();
            self.stats.on_enqueue(1);
            tx.send(Request::Scan { metric, q: q.to_vec(), rows, n, reply: reply_tx })
                .expect("xla-service gone");
        }
        reply_rx
            .recv()
            .expect("xla-service dropped reply")
            .expect("xla scan failed")
    }
}

impl DistanceEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn scan(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        if ids.is_empty() {
            return 0;
        }
        // Gather candidate rows into a dense buffer for the device.
        let mut rows = Vec::with_capacity(ids.len() * dim);
        for &id in ids {
            rows.extend_from_slice(&data[id as usize * dim..(id as usize + 1) * dim]);
        }
        let dists = self.scan_remote(metric, q, rows, ids.len());
        for (&id, &d) in ids.iter().zip(&dists) {
            push_scored(topk, id_base, id, d, labels);
        }
        ids.len() as u64
    }

    /// Contiguous ranges need no id materialization OR gather: the rows
    /// are sliced straight out of the shard and shipped in ONE service
    /// round trip (the chunked trait default would cost one lock/channel/
    /// dispatch cycle per 256 ids).
    fn scan_range(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        let n = (range.end - range.start) as usize;
        if n == 0 {
            return 0;
        }
        let rows = data[range.start as usize * dim..range.end as usize * dim].to_vec();
        let dists = self.scan_remote(metric, q, rows, n);
        for (i, &d) in dists.iter().enumerate() {
            push_scored(topk, id_base, range.start + i as u32, d, labels);
        }
        n as u64
    }
}
