//! PJRT execution of the AOT artifacts (single-threaded core).
//!
//! Wraps the `xla` crate: CPU client → `HloModuleProto::from_text_file` →
//! compile → execute. `PjRtClient` is `Rc`-based (neither `Send` nor
//! `Sync`), so this type must live on one thread;
//! [`crate::runtime::service`] provides the thread-safe façade the worker
//! pool uses.
//!
//! The `xla` crate cannot be fetched in the offline build environment, so
//! the real implementation is gated behind the `xla` cargo feature; the
//! default build compiles an API-identical stub whose constructors return
//! an error. Everything downstream (`XlaService`, `EngineKind::Xla`, the
//! XLA integration tests) already handles runtime construction failure,
//! so the request path degrades to the native engine.

use anyhow::Result;

#[cfg(feature = "xla")]
use anyhow::{anyhow, bail, Context};

use crate::lsh::family::Metric;
use crate::runtime::artifacts::Manifest;

/// Distance value the kernels assign to padding rows (ref.py PAD_DIST).
pub const PAD_DIST: f32 = 1e9;

/// One compiled scan executable.
#[cfg(feature = "xla")]
struct ScanExe {
    bc: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// Single-threaded PJRT runtime holding compiled scan kernels.
#[cfg(feature = "xla")]
pub struct XlaRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    /// Ascending-bc ladders per metric.
    l1: Vec<ScanExe>,
    cosine: Vec<ScanExe>,
    pub dim: usize,
    /// Cumulative executions (diagnostics).
    pub calls: std::cell::Cell<u64>,
}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Compile every scan artifact in the manifest on a fresh CPU client.
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut l1 = Vec::new();
        let mut cosine = Vec::new();
        for kind in ["l1_scan", "cosine_scan"] {
            for meta in manifest.scan_ladder(kind) {
                let proto = xla::HloModuleProto::from_text_file(&meta.file)
                    .map_err(|e| anyhow!("loading {:?}: {e:?}", meta.file))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?;
                let entry = ScanExe { bc: meta.bc.unwrap(), exe };
                if kind == "l1_scan" {
                    l1.push(entry);
                } else {
                    cosine.push(entry);
                }
            }
        }
        if l1.is_empty() {
            bail!("manifest has no l1_scan artifacts");
        }
        Ok(Self { client, l1, cosine, dim: manifest.dim, calls: std::cell::Cell::new(0) })
    }

    /// Convenience: discover artifacts and build.
    pub fn discover() -> Result<Self> {
        let manifest = Manifest::discover()?;
        Self::from_manifest(&manifest)
    }

    fn ladder(&self, metric: Metric) -> &[ScanExe] {
        match metric {
            Metric::L1 => &self.l1,
            Metric::Cosine => &self.cosine,
        }
    }

    /// Largest compiled batch for a metric.
    pub fn max_batch(&self, metric: Metric) -> usize {
        self.ladder(metric).last().map(|e| e.bc).unwrap_or(0)
    }

    /// Smallest compiled batch that fits `n` rows (or the max batch, used
    /// with chunking).
    fn pick(&self, metric: Metric, n: usize) -> &ScanExe {
        let ladder = self.ladder(metric);
        ladder.iter().find(|e| e.bc >= n).unwrap_or_else(|| ladder.last().unwrap())
    }

    /// Distances from `q` to `rows` (row-major `n × dim`, n arbitrary —
    /// chunked over the ladder). Output length == n, in row order.
    pub fn scan_rows(&self, metric: Metric, q: &[f32], rows: &[f32], n: usize) -> Result<Vec<f32>> {
        assert_eq!(q.len(), self.dim);
        assert_eq!(rows.len(), n * self.dim);
        if self.ladder(metric).is_empty() {
            bail!("no {metric:?} artifacts compiled");
        }
        let mut out = Vec::with_capacity(n);
        let max = self.max_batch(metric);
        let mut off = 0usize;
        while off < n {
            let take = (n - off).min(max);
            let exe = self.pick(metric, take);
            let dists = self.execute_one(exe, q, &rows[off * self.dim..(off + take) * self.dim], take)?;
            out.extend_from_slice(&dists[..take]);
            off += take;
        }
        Ok(out)
    }

    /// Run one padded batch through a compiled executable.
    fn execute_one(&self, exe: &ScanExe, q: &[f32], rows: &[f32], n_real: usize) -> Result<Vec<f32>> {
        let bc = exe.bc;
        debug_assert!(n_real <= bc);
        // Pad candidates with zero rows, mask marks them invalid.
        let mut c = vec![0f32; bc * self.dim];
        c[..n_real * self.dim].copy_from_slice(rows);
        let mut mask = vec![0f32; bc];
        for m in mask.iter_mut().take(n_real) {
            *m = 1.0;
        }
        let q_lit = xla::Literal::vec1(q)
            .reshape(&[1, self.dim as i64])
            .map_err(|e| anyhow!("reshape q: {e:?}"))?;
        let c_lit = xla::Literal::vec1(&c)
            .reshape(&[bc as i64, self.dim as i64])
            .map_err(|e| anyhow!("reshape c: {e:?}"))?;
        let m_lit = xla::Literal::vec1(&mask);
        let result = exe
            .exe
            .execute::<xla::Literal>(&[q_lit, c_lit, m_lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        self.calls.set(self.calls.get() + 1);
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let tuple = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        let values: Vec<f32> = tuple.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if values.len() != bc {
            bail!("expected {bc} distances, got {}", values.len());
        }
        Ok(values)
    }
}

/// Offline stub: same API, every entry point reports the missing feature.
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    pub dim: usize,
    pub calls: std::cell::Cell<u64>,
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    pub fn from_manifest(_manifest: &Manifest) -> Result<Self> {
        anyhow::bail!(
            "built without the `xla` cargo feature — the PJRT engine is unavailable \
             (use the native engine, or rebuild with --features xla and the xla crate)"
        )
    }

    pub fn discover() -> Result<Self> {
        anyhow::bail!(
            "built without the `xla` cargo feature — the PJRT engine is unavailable \
             (use the native engine, or rebuild with --features xla and the xla crate)"
        )
    }

    pub fn max_batch(&self, _metric: Metric) -> usize {
        0
    }

    pub fn scan_rows(
        &self,
        _metric: Metric,
        _q: &[f32],
        _rows: &[f32],
        _n: usize,
    ) -> Result<Vec<f32>> {
        anyhow::bail!("xla feature disabled")
    }
}
