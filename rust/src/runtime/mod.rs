//! AOT runtime: artifact catalog, PJRT execution, and the thread-safe
//! XLA distance-engine service. Python authors + lowers the kernels once
//! (`make artifacts`); this module is everything the request path needs.

pub mod artifacts;
pub mod pjrt;
pub mod service;

pub use artifacts::{locate, ArtifactError, Manifest};
pub use pjrt::{XlaRuntime, PAD_DIST};
pub use service::{
    CutCounters, EdgeCounters, EdgeEndpoint, EdgeStats, EndpointStats, FailoverCounters,
    FailoverStats, IngestCounters, IngestStats, LaneCounters, QueueStats, XlaEngine, XlaService,
};
