//! Runtime services and the serving-path observability contract.
//!
//! Besides the AOT/XLA execution pieces (artifact catalog, PJRT runtime,
//! thread-safe distance-engine service), this module defines **every
//! counter the cluster exports** and what each costs on the hot path:
//!
//! - [`service`] — lock-free relaxed-atomic counter blocks, one per
//!   subsystem: [`QueueStats`] (admission + service channel depth /
//!   throughput / rejections), [`CutCounters`] (why each batch was cut),
//!   [`LaneCounters`] (per-class dispatches, overruns, partials, sheds,
//!   inserts), [`IngestCounters`] (live-index growth and seals),
//!   [`FailoverCounters`] (hedges, failovers, synthesized sheds, replica
//!   health), and [`EdgeCounters`] (per-HTTP-endpoint requests / errors /
//!   latency histogram). Cost: a handful of relaxed `fetch_add`s per
//!   event; never a lock.
//! - [`hist`] — wait-free power-of-two-bucket [`Histogram`]s with
//!   mergeable [`HistSnapshot`]s and p50/p90/p99/p999 extraction. Cost:
//!   three relaxed `fetch_add`s per recorded value.
//! - [`trace`] — the end-to-end [`Tracer`]: per-lane queue-wait /
//!   service / e2e and per-shard network / scan histograms (always on),
//!   plus opt-in per-request span collection and the slow-query ring
//!   buffer. Cost when not collecting spans: the clock reads the stages
//!   already take plus histogram records; span collection adds a mutex
//!   per stage boundary and is a debugging tier.
//!
//! Everything above is scraped in one place: the serving edge's
//! `GET /metrics` (Prometheus text exposition) renders every family, and
//! `GET /v1/debug/slow` dumps the slow-query ring as JSON.

pub mod artifacts;
pub mod hist;
pub mod pjrt;
pub mod service;
pub mod trace;

pub use artifacts::{locate, ArtifactError, Manifest};
pub use hist::{HistSnapshot, Histogram};
pub use pjrt::{XlaRuntime, PAD_DIST};
pub use service::{
    decode_reject_counts, note_decode_reject, CauseCounters, CutCounters, EdgeCounters,
    EdgeEndpoint, EdgeStats, EndpointStats, FailoverCounters, FailoverStats, IngestCounters,
    IngestStats, LaneCounters, QueueStats, XlaEngine, XlaService,
};
pub use trace::{LaneHistStats, NodeSpan, QueryTrace, ShardHistStats, Span, Tracer};
