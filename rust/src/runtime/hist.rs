//! Lock-free power-of-two-bucket latency histograms.
//!
//! The serving path records latencies from many threads at once (edge
//! connection handlers, admission dispatchers, shard replica runners), so
//! the recorder must be wait-free: [`Histogram::record`] is three relaxed
//! `fetch_add`s and nothing else — no locks, no allocation, no branches
//! beyond computing the bucket index. Reads happen rarely (a `/metrics`
//! scrape, a stats snapshot) and tolerate being torn across concurrent
//! writers; every counter is monotone so a snapshot is always a valid
//! "some moment at or before now" view.
//!
//! Buckets are powers of two: bucket 0 holds the value 0, bucket `i >= 1`
//! holds values `v` with `2^(i-1) <= v < 2^i`, and the last bucket
//! saturates (everything from `2^62` up, including `u64::MAX`). That
//! gives ~5% worst-case relative error on percentile *upper bounds* over
//! the full `u64` range with a fixed 64-slot table — the classic HdrHistogram
//! tradeoff collapsed to its cheapest form. Percentiles extracted from a
//! [`HistSnapshot`] report the *upper bound* of the bucket holding the
//! ranked observation, so they never under-report a tail.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: one for zero plus one per possible leading-bit
/// position of a nonzero `u64` (63 of them, with the top one saturating).
pub const NUM_BUCKETS: usize = 64;

/// Bucket index for a recorded value. 0 maps to bucket 0; a nonzero `v`
/// maps to `min(64 - leading_zeros(v), 63)` so bucket `i` covers
/// `[2^(i-1), 2^i)` and bucket 63 saturates from `2^62` upward.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(NUM_BUCKETS - 1)
    }
}

/// Inclusive upper bound of a bucket, used when reporting percentiles.
/// Bucket 0 is exactly zero; bucket `i` covers up to `2^i - 1`; the
/// saturating last bucket reports `u64::MAX`.
#[inline]
pub fn bucket_upper_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= NUM_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// A wait-free histogram of `u64` observations (typically microseconds or
/// nanoseconds). All methods take `&self`; share it via `Arc` or embed it
/// in an already-shared stats block.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation. Three relaxed `fetch_add`s; safe from any
    /// thread. The running sum wraps on overflow rather than saturating —
    /// at nanosecond scale that takes centuries of recorded time.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Copy the current counters out. Not atomic across buckets — fine
    /// for monitoring, where every counter is monotone.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; NUM_BUCKETS];
        for (slot, b) in buckets.iter_mut().zip(self.buckets.iter()) {
            *slot = b.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// A plain-data copy of a [`Histogram`] at some moment: mergeable,
/// comparable, and the unit the `/metrics` exposition and stats JSON are
/// built from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; NUM_BUCKETS],
    pub sum: u64,
    pub count: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; NUM_BUCKETS], sum: 0, count: 0 }
    }
}

impl HistSnapshot {
    /// Fold another snapshot into this one (bucket-wise addition). Used to
    /// aggregate per-shard or per-lane histograms into a cluster view.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    /// Upper bound of the bucket holding the `q`-ranked observation
    /// (`0.0 < q <= 1.0`). Returns 0 for an empty histogram. Never
    /// under-reports: the true percentile is `<=` the returned value.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        // Rank of the target observation, 1-based, clamped into range.
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper_bound(i);
            }
        }
        bucket_upper_bound(NUM_BUCKETS - 1)
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.percentile(0.90)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.percentile(0.999)
    }

    /// Exact mean of recorded values (from the running sum, not buckets).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bucket_boundaries_at_powers_of_two() {
        // Zero is its own bucket.
        assert_eq!(bucket_index(0), 0);
        // 1 = 2^0 opens bucket 1; each power of two opens the next.
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        for i in 1..62 {
            let lo = 1u64 << (i - 1);
            let hi = (1u64 << i) - 1;
            assert_eq!(bucket_index(lo), i, "low edge of bucket {i}");
            assert_eq!(bucket_index(hi), i, "high edge of bucket {i}");
        }
    }

    #[test]
    fn top_bucket_saturates_at_u64_max() {
        assert_eq!(bucket_index(1u64 << 62), NUM_BUCKETS - 1);
        assert_eq!(bucket_index(1u64 << 63), NUM_BUCKETS - 1);
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
        assert_eq!(bucket_upper_bound(NUM_BUCKETS - 1), u64::MAX);

        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.buckets[NUM_BUCKETS - 1], 2);
        // Sum wraps (documented); count and buckets stay exact.
        assert_eq!(s.p50(), u64::MAX);
        assert_eq!(s.p999(), u64::MAX);
    }

    #[test]
    fn upper_bounds_cover_their_buckets() {
        for i in 0..NUM_BUCKETS {
            let ub = bucket_upper_bound(i);
            assert_eq!(bucket_index(ub), i, "upper bound of bucket {i} lands in it");
        }
    }

    #[test]
    fn percentiles_on_known_distribution() {
        let h = Histogram::new();
        // 100 observations: 50 at 10 (bucket 4, ub 15), 40 at 100
        // (bucket 7, ub 127), 9 at 1000 (bucket 10, ub 1023), 1 at
        // 100_000 (bucket 17, ub 131071).
        for _ in 0..50 {
            h.record(10);
        }
        for _ in 0..40 {
            h.record(100);
        }
        for _ in 0..9 {
            h.record(1000);
        }
        h.record(100_000);
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.sum, 50 * 10 + 40 * 100 + 9 * 1000 + 100_000);
        assert_eq!(s.p50(), 15);
        assert_eq!(s.p90(), 127);
        assert_eq!(s.p99(), 1023);
        assert_eq!(s.p999(), 131_071);
        assert!((s.mean() - 1135.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_edge_cases() {
        let empty = HistSnapshot::default();
        assert_eq!(empty.percentile(0.5), 0);
        assert_eq!(empty.mean(), 0.0);

        let h = Histogram::new();
        h.record(7);
        let s = h.snapshot();
        // A single observation is every percentile.
        assert_eq!(s.percentile(0.001), 7);
        assert_eq!(s.percentile(1.0), 7);
    }

    #[test]
    fn merge_is_bucketwise_addition() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [0u64, 1, 5, 1000] {
            a.record(v);
        }
        for v in [3u64, 5, u64::MAX] {
            b.record(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());

        let seq = Histogram::new();
        for v in [0u64, 1, 5, 1000, 3, 5, u64::MAX] {
            seq.record(v);
        }
        assert_eq!(merged, seq.snapshot());
    }

    #[test]
    fn concurrent_merge_equals_sequential() {
        let per_thread: Vec<Vec<u64>> = (0..4)
            .map(|t| (0..500).map(|i| (t * 1000 + i * 37) as u64 % 5000).collect())
            .collect();

        // Concurrent: 4 threads hammer one shared histogram.
        let shared = Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for vals in per_thread.clone() {
            let h = Arc::clone(&shared);
            handles.push(std::thread::spawn(move || {
                for v in vals {
                    h.record(v);
                }
            }));
        }
        for jh in handles {
            jh.join().unwrap();
        }

        // Sequential reference over the same multiset.
        let seq = Histogram::new();
        for vals in &per_thread {
            for &v in vals {
                seq.record(v);
            }
        }
        assert_eq!(shared.snapshot(), seq.snapshot());
    }
}
