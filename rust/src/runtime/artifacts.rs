//! AOT artifact catalog: locate `artifacts/`, parse `manifest.json`
//! (written by `python -m compile.aot`), and resolve kernel names to
//! HLO-text files.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Metadata for one lowered kernel.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub file: PathBuf,
    /// Candidate batch size (scan kernels).
    pub bc: Option<usize>,
    /// Query batch size (scan kernels).
    pub bq: Option<usize>,
    pub dim: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dim: usize,
    pub artifacts: Vec<ArtifactMeta>,
}

#[derive(Debug)]
pub enum ArtifactError {
    NotFound(Vec<PathBuf>),
    Io(PathBuf, std::io::Error),
    Parse(String),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::NotFound(tried) => {
                write!(f, "artifacts directory not found (tried {tried:?}); run `make artifacts` first")
            }
            ArtifactError::Io(path, e) => write!(f, "failed reading {}: {e}", path.display()),
            ArtifactError::Parse(msg) => write!(f, "manifest parse error: {msg}"),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io(_, e) => Some(e),
            _ => None,
        }
    }
}

/// Locate the artifacts directory: `$DSLSH_ARTIFACTS`, `./artifacts`, or
/// next to the executable.
pub fn locate() -> Result<PathBuf, ArtifactError> {
    let mut tried = Vec::new();
    if let Ok(dir) = std::env::var("DSLSH_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Ok(p);
        }
        tried.push(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.join("manifest.json").exists() {
        return Ok(cwd);
    }
    tried.push(cwd);
    if let Ok(exe) = std::env::current_exe() {
        // target/release/dslsh -> repo root/artifacts
        for ancestor in exe.ancestors().skip(1).take(4) {
            let p = ancestor.join("artifacts");
            if p.join("manifest.json").exists() {
                return Ok(p);
            }
            tried.push(p);
        }
    }
    Err(ArtifactError::NotFound(tried))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ArtifactError> {
        let path = dir.join("manifest.json");
        let text =
            std::fs::read_to_string(&path).map_err(|e| ArtifactError::Io(path.clone(), e))?;
        let json = Json::parse(&text).map_err(|e| ArtifactError::Parse(e.to_string()))?;
        let dim = json
            .get("dim")
            .and_then(Json::as_usize)
            .ok_or_else(|| ArtifactError::Parse("missing dim".into()))?;
        let arts = json
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| ArtifactError::Parse("missing artifacts".into()))?;
        let mut artifacts = Vec::new();
        for (name, meta) in arts.iter() {
            let kind = meta
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| ArtifactError::Parse(format!("{name}: missing kind")))?;
            let file = meta
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| ArtifactError::Parse(format!("{name}: missing file")))?;
            artifacts.push(ArtifactMeta {
                name: name.clone(),
                kind: kind.to_string(),
                file: dir.join(file),
                bc: meta.get("bc").and_then(Json::as_usize),
                bq: meta.get("bq").and_then(Json::as_usize),
                dim: meta.get("d").and_then(Json::as_usize).unwrap_or(dim),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), dim, artifacts })
    }

    /// Discover + load in one step.
    pub fn discover() -> Result<Manifest, ArtifactError> {
        Manifest::load(&locate()?)
    }

    /// Scan kernels of a kind, sorted ascending by batch size.
    pub fn scan_ladder(&self, kind: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> =
            self.artifacts.iter().filter(|a| a.kind == kind && a.bc.is_some()).collect();
        v.sort_by_key(|a| a.bc.unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_manifest_and_sorts_ladder() {
        let dir = std::env::temp_dir().join("dslsh_manifest_test");
        write_manifest(
            &dir,
            r#"{"dim": 30, "bq": 1, "artifacts": {
                "l1_scan_b2048": {"kind": "l1_scan", "bq": 1, "bc": 2048, "d": 30, "file": "a.hlo.txt"},
                "l1_scan_b256": {"kind": "l1_scan", "bq": 1, "bc": 256, "d": 30, "file": "b.hlo.txt"},
                "hash_outer_l120_m125": {"kind": "hash_outer", "l": 120, "m": 125, "d": 30, "file": "c.hlo.txt"}
            }}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.dim, 30);
        assert_eq!(m.artifacts.len(), 3);
        let ladder = m.scan_ladder("l1_scan");
        assert_eq!(ladder.len(), 2);
        assert_eq!(ladder[0].bc, Some(256));
        assert_eq!(ladder[1].bc, Some(2048));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_malformed_manifest() {
        let dir = std::env::temp_dir().join("dslsh_manifest_bad");
        write_manifest(&dir, r#"{"artifacts": {}}"#);
        assert!(matches!(Manifest::load(&dir), Err(ArtifactError::Parse(_))));
        write_manifest(&dir, "not json");
        assert!(matches!(Manifest::load(&dir), Err(ArtifactError::Parse(_))));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_error_lists_candidates() {
        let m = Manifest::load(Path::new("/nonexistent/dslsh"));
        assert!(matches!(m, Err(ArtifactError::Io(..))));
    }
}
