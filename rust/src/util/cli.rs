//! Hand-rolled command-line parsing (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, repeated keys,
//! and positional arguments. Typed getters return an error naming the
//! offending flag so CLI mistakes fail loudly.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    Missing(String),
    BadValue(String, &'static str, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(name) => write!(f, "unknown flag --{name}"),
            CliError::Missing(name) => write!(f, "missing required flag --{name}"),
            CliError::BadValue(name, want, got) => {
                write!(f, "flag --{name}: expected {want}, got '{got}'")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Parsed arguments: positionals in order, plus key→values multimap.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list. `valued` lists flags that consume
    /// a following token when used in `--key value` form.
    pub fn parse_from<I, S>(tokens: I, valued: &[&'static str]) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut it = tokens.into_iter().map(Into::into).peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if valued.contains(&rest) {
                    match it.next() {
                        Some(v) => args.options.entry(rest.to_string()).or_default().push(v),
                        None => args.flags.push(rest.to_string()), // error at typed access
                    }
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse process args after the program name (and optional subcommand
    /// tokens already consumed by the caller).
    pub fn parse_env(skip: usize, valued: &[&'static str]) -> Args {
        Args::parse_from(std::env::args().skip(1 + skip), valued)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options.get(name).map(|v| v.iter().map(String::as_str).collect()).unwrap_or_default()
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get_str(name).unwrap_or(default)
    }

    pub fn require_str(&self, name: &str) -> Result<&str, CliError> {
        self.get_str(name).ok_or_else(|| CliError::Missing(name.to_string()))
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get_str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| CliError::BadValue(name.into(), "integer", s.into())),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        Ok(self.get_usize(name)?.unwrap_or(default))
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.get_str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<u64>()
                .map(Some)
                .map_err(|_| CliError::BadValue(name.into(), "integer", s.into())),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        Ok(self.get_u64(name)?.unwrap_or(default))
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get_str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| CliError::BadValue(name.into(), "number", s.into())),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        Ok(self.get_f64(name)?.unwrap_or(default))
    }

    /// Comma-separated list of usizes, e.g. `--pv 8,16,24`.
    pub fn usize_list(&self, name: &str) -> Result<Option<Vec<usize>>, CliError> {
        match self.get_str(name) {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|_| CliError::BadValue(name.into(), "integer list", s.into()))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse_from(tokens.iter().copied(), &["n", "seed", "out", "pv", "alpha"])
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["table2", "--full", "--n", "1000"]);
        assert_eq!(a.positional, vec!["table2"]);
        assert!(a.has_flag("full"));
        assert!(!a.has_flag("absent"));
        assert_eq!(a.get_usize("n").unwrap(), Some(1000));
    }

    #[test]
    fn equals_form_and_repeats() {
        let a = parse(&["--n=5", "--n", "7", "--out=/tmp/x"]);
        assert_eq!(a.get_usize("n").unwrap(), Some(7)); // last wins
        assert_eq!(a.get_all("n"), vec!["5", "7"]);
        assert_eq!(a.get_str("out"), Some("/tmp/x"));
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["--n", "xyz"]);
        assert!(a.get_usize("n").is_err());
        assert!(a.require_str("missing").is_err());
        assert_eq!(a.usize_or("absent", 9).unwrap(), 9);
    }

    #[test]
    fn float_and_lists() {
        let a = parse(&["--alpha", "0.005", "--pv", "8,16,24"]);
        assert_eq!(a.get_f64("alpha").unwrap(), Some(0.005));
        assert_eq!(a.usize_list("pv").unwrap().unwrap(), vec![8, 16, 24]);
        assert!(parse(&["--pv", "8,x"]).usize_list("pv").is_err());
    }

    #[test]
    fn boolean_flag_without_value() {
        let a = parse(&["--verbose", "pos1", "pos2"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }
}
