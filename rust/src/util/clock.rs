//! Injectable monotonic time sources.
//!
//! Everything on the serving path that makes a *time-dependent* decision
//! — the admission cutter choosing when to cut, a node deciding whether a
//! scan's budget is blown — reads time through the [`Clock`] trait instead
//! of the wall clock, so every decision is reproducible in tests:
//!
//! * [`SystemClock`] — production: monotonic nanoseconds since start;
//! * [`MockClock`] — tests: time moves only when the test says so;
//! * [`TickClock`] — tests: time advances by a fixed step on every read,
//!   which makes "work takes time" deterministic — a scan that checks the
//!   clock once per table blows its deadline after exactly
//!   `deadline / step` checks, independent of the machine.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic time source for scheduling and budget-enforcement decisions.
/// Injecting it is what makes those decisions reproducible in tests.
pub trait Clock: Send + Sync {
    /// Nanoseconds since an arbitrary fixed origin. Must be monotone.
    fn now_ns(&self) -> u64;
}

/// Production clock: monotonic nanoseconds since construction.
#[derive(Debug)]
pub struct SystemClock {
    origin: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { origin: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> SystemClock {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// Test clock: time only moves when the test says so.
#[derive(Debug, Default)]
pub struct MockClock {
    ns: AtomicU64,
}

impl MockClock {
    pub fn new(start_ns: u64) -> MockClock {
        MockClock { ns: AtomicU64::new(start_ns) }
    }

    pub fn set_ns(&self, t: u64) {
        self.ns.store(t, Ordering::SeqCst);
    }

    pub fn advance_ns(&self, d: u64) {
        self.ns.fetch_add(d, Ordering::SeqCst);
    }

    pub fn advance(&self, d: Duration) {
        self.advance_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }
}

impl Clock for MockClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::SeqCst)
    }
}

/// Test clock whose reads COST time: every `now_ns` returns the current
/// value and then advances it by `step_ns`. A budget-enforced scan that
/// checks the clock once per unit of work therefore stops after exactly
/// `ceil(deadline / step)` checks — a pure function of the deadline, not
/// of machine speed — which is what makes mid-scan partial results
/// assertable bit-for-bit (see `rust/tests/budget_enforcement.rs`).
#[derive(Debug)]
pub struct TickClock {
    ns: AtomicU64,
    step: u64,
}

impl TickClock {
    pub fn new(start_ns: u64, step_ns: u64) -> TickClock {
        TickClock { ns: AtomicU64::new(start_ns), step: step_ns }
    }
}

impl Clock for TickClock {
    fn now_ns(&self) -> u64 {
        self.ns.fetch_add(self.step, Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotone() {
        let c = SystemClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn mock_clock_moves_only_on_command() {
        let c = MockClock::new(100);
        assert_eq!(c.now_ns(), 100);
        assert_eq!(c.now_ns(), 100);
        c.advance_ns(50);
        assert_eq!(c.now_ns(), 150);
        c.set_ns(7);
        assert_eq!(c.now_ns(), 7);
        c.advance(Duration::from_nanos(3));
        assert_eq!(c.now_ns(), 10);
    }

    #[test]
    fn tick_clock_charges_a_step_per_read() {
        let c = TickClock::new(1000, 10);
        assert_eq!(c.now_ns(), 1000);
        assert_eq!(c.now_ns(), 1010);
        assert_eq!(c.now_ns(), 1020);
    }
}
