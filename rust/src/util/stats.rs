//! Descriptive statistics used by the experiment harness: medians,
//! percentiles, and the 95% confidence interval **of the median** that the
//! paper reports for its comparison counts (Tables 2–3, Figures 3–4).
//!
//! The median CI uses the standard distribution-free order-statistic
//! construction: for a sample of size `n`, the interval
//! `[x_(l), x_(u)]` with `l, u` chosen from the Binomial(n, 1/2)
//! distribution covers the population median with ≥95% probability.
//! A bootstrap alternative is provided as a cross-check.

use crate::util::rng::Xoshiro256;

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (0 for n < 2).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Sorted copy helper.
fn sorted(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in stats input"));
    v
}

/// Median (average of the two central order statistics for even n).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    let v = sorted(xs);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Linear-interpolation percentile. `q` is clamped to [0, 1] — callers
/// computing ranks like `alpha / 2` or `1 − alpha / 2` can drift a ULP
/// past the endpoints, and an out-of-range rank must degrade to the
/// nearest order statistic, never index out of bounds. NaN `q` is a
/// caller bug (debug assert); release builds treat it as `q = 0`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    debug_assert!(!q.is_nan(), "percentile rank is NaN");
    let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
    let v = sorted(xs);
    if v.len() == 1 {
        return v[0];
    }
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

/// A two-sided interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    pub fn contains(&self, x: f64) -> bool {
        self.lo <= x && x <= self.hi
    }
    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }
}

/// ln(n!) via Stirling series for large n, table for small n.
fn ln_factorial(n: u64) -> f64 {
    if n < 2 {
        return 0.0;
    }
    if n < 32 {
        let mut acc = 0.0;
        for k in 2..=n {
            acc += (k as f64).ln();
        }
        return acc;
    }
    // Stirling with 1/(12n) and 1/(360n^3) corrections — plenty for CI math.
    let nf = n as f64;
    nf * nf.ln() - nf + 0.5 * (2.0 * std::f64::consts::PI * nf).ln() + 1.0 / (12.0 * nf)
        - 1.0 / (360.0 * nf * nf * nf)
}

/// Binomial(n, 1/2) PMF at k, computed in log space to avoid overflow.
fn binom_half_pmf(n: u64, k: u64) -> f64 {
    let ln = ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
        + (n as f64) * 0.5f64.ln();
    ln.exp()
}

/// Distribution-free CI for the **median** via binomial order statistics.
///
/// Returns the narrowest symmetric-in-rank interval `[x_(l+1), x_(u)]`
/// (1-based order statistics) whose Binomial(n, 1/2) coverage is at least
/// `conf`. For tiny n where no interval achieves the coverage, returns the
/// full sample range.
pub fn median_ci(xs: &[f64], conf: f64) -> Interval {
    assert!(!xs.is_empty());
    let v = sorted(xs);
    let n = v.len() as u64;
    if n < 6 {
        return Interval { lo: v[0], hi: v[v.len() - 1] };
    }
    // Find the largest l such that P[l < X <= n-l] >= conf, where
    // X ~ Binomial(n, 1/2). Coverage of [x_(l+1), x_(n-l)] is
    // P[l <= X <= n-l-1]... we use the classic symmetric construction:
    // coverage(l) = sum_{k=l}^{n-l} C(n,k)/2^n  (interval [x_(l+1), x_(n-l)]
    // in 1-based ranks covers the median with that probability).
    let mut best_l = 0u64;
    let mut l = n / 2;
    loop {
        // coverage for this l
        let mut cov = 0.0;
        for k in l..=(n - l) {
            cov += binom_half_pmf(n, k);
        }
        if cov >= conf {
            best_l = l;
            break;
        }
        if l == 0 {
            break;
        }
        l -= 1;
    }
    if best_l == 0 {
        return Interval { lo: v[0], hi: v[v.len() - 1] };
    }
    Interval {
        lo: v[(best_l) as usize],        // x_(l+1) in 1-based = index l
        hi: v[(n - best_l - 1) as usize], // x_(n-l) in 1-based = index n-l-1
    }
}

/// Bootstrap percentile CI for the median — used in tests to cross-check
/// [`median_ci`], and available to the harness via `--ci bootstrap`.
pub fn median_ci_bootstrap(xs: &[f64], conf: f64, reps: usize, seed: u64) -> Interval {
    assert!(!xs.is_empty());
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut meds = Vec::with_capacity(reps);
    let mut resample = vec![0.0; xs.len()];
    for _ in 0..reps {
        for slot in resample.iter_mut() {
            *slot = xs[rng.gen_index(xs.len())];
        }
        meds.push(median(&resample));
    }
    let alpha = 1.0 - conf;
    Interval {
        lo: percentile(&meds, alpha / 2.0),
        hi: percentile(&meds, 1.0 - alpha / 2.0),
    }
}

/// Online accumulator for min/max/mean — used by latency tracking in the
/// serving path where storing every sample would be wasteful.
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[5.0]), 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 1.0), 40.0);
        assert!((percentile(&xs, 0.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_edge_cases() {
        // Table-driven: (input, q, expected). Out-of-range q clamps to
        // the nearest order statistic instead of indexing out of bounds.
        let multi = [10.0, 20.0, 30.0, 40.0];
        let single = [7.0];
        let cases: &[(&[f64], f64, f64)] = &[
            (&single, 0.0, 7.0),
            (&single, 0.5, 7.0),
            (&single, 1.0, 7.0),
            (&single, -3.0, 7.0),
            (&multi, 0.0, 10.0),
            (&multi, 1.0, 40.0),
            (&multi, -0.25, 10.0),          // clamps to q = 0
            (&multi, 1.25, 40.0),           // clamps to q = 1
            (&multi, 1.0 + 1e-12, 40.0),    // one-ULP drift past the end
            (&multi, 0.25, 17.5),
            (&multi, 1.0 / 3.0, 20.0),
        ];
        for &(xs, q, want) in cases {
            let got = percentile(xs, q);
            assert!((got - want).abs() < 1e-9, "q={q}: got {got}, want {want}");
        }
    }

    #[test]
    #[should_panic(expected = "percentile of empty slice")]
    fn percentile_empty_panics() {
        percentile(&[], 0.5);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "percentile rank is NaN")]
    fn percentile_nan_rank_debug_asserts() {
        percentile(&[1.0, 2.0], f64::NAN);
    }

    #[test]
    fn ln_factorial_matches_direct() {
        let direct: f64 = (2..=40).map(|k| (k as f64).ln()).sum();
        assert!((ln_factorial(40) - direct).abs() < 1e-6);
    }

    #[test]
    fn binom_pmf_sums_to_one() {
        for n in [10u64, 100, 2000] {
            let total: f64 = (0..=n).map(|k| binom_half_pmf(n, k)).sum();
            assert!((total - 1.0).abs() < 1e-9, "n={n} total={total}");
        }
    }

    #[test]
    fn median_ci_contains_median_and_shrinks() {
        let mut rng = Xoshiro256::seed_from_u64(99);
        let small: Vec<f64> = (0..50).map(|_| rng.gen_normal(10.0, 2.0)).collect();
        let large: Vec<f64> = (0..2000).map(|_| rng.gen_normal(10.0, 2.0)).collect();
        let ci_s = median_ci(&small, 0.95);
        let ci_l = median_ci(&large, 0.95);
        assert!(ci_s.contains(median(&small)));
        assert!(ci_l.contains(median(&large)));
        assert!(ci_l.width() < ci_s.width(), "CI must shrink with n");
    }

    #[test]
    fn median_ci_coverage_simulation() {
        // Empirical coverage of the 95% CI over repeated draws from a
        // known-median distribution should be >= ~92%.
        let mut rng = Xoshiro256::seed_from_u64(123);
        let mut covered = 0;
        let reps = 400;
        for _ in 0..reps {
            let xs: Vec<f64> = (0..101).map(|_| rng.gen_normal(0.0, 1.0)).collect();
            if median_ci(&xs, 0.95).contains(0.0) {
                covered += 1;
            }
        }
        let rate = covered as f64 / reps as f64;
        assert!(rate > 0.90, "coverage={rate}");
    }

    #[test]
    fn bootstrap_agrees_with_order_statistics() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let xs: Vec<f64> = (0..500).map(|_| rng.gen_normal(50.0, 5.0)).collect();
        let a = median_ci(&xs, 0.95);
        let b = median_ci_bootstrap(&xs, 0.95, 2000, 11);
        // The two constructions should roughly agree in location.
        assert!((a.lo - b.lo).abs() < 1.0, "a={a:?} b={b:?}");
        assert!((a.hi - b.hi).abs() < 1.0, "a={a:?} b={b:?}");
    }

    #[test]
    fn running_accumulator() {
        let mut r = Running::new();
        for x in [1.0, 2.0, 3.0] {
            r.push(x);
        }
        assert_eq!(r.n, 3);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 3.0);
        assert!((r.mean() - 2.0).abs() < 1e-12);
    }
}
