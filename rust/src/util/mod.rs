//! Infrastructure substrates built from scratch for the offline
//! environment: PRNG, JSON, statistics, structured parallelism, logging,
//! CLI parsing and binary codecs.

pub mod bytes;
pub mod cli;
pub mod clock;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stamp;
pub mod stats;
pub mod threadpool;
