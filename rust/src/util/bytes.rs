//! Little-endian binary encoding helpers for dataset files and the TCP
//! wire protocol (no `serde`/`bincode` offline). All multi-byte values are
//! little-endian; collections are length-prefixed with `u64`.

use std::io::{self, Read, Write};

#[derive(Debug)]
pub enum CodecError {
    Io(io::Error),
    BadMagic { expected: u64, got: u64 },
    BadVersion(u32),
    TooLong(u64, u64),
    BadUtf8,
    BadTag(u32, &'static str),
    /// A batch frame whose item count × dimensionality does not match the
    /// shipped payload (hostile/corrupt peer).
    BadGeometry { items: u64, len: u64, dim: u64 },
}

impl CodecError {
    /// Stable cause label for per-kind drop counters (the TCP server
    /// attributes decode rejections by this, see
    /// `runtime::service::note_decode_reject`).
    pub fn kind(&self) -> &'static str {
        match self {
            CodecError::Io(_) => "io",
            CodecError::BadMagic { .. } => "bad_magic",
            CodecError::BadVersion(_) => "bad_version",
            CodecError::TooLong(..) => "too_long",
            CodecError::BadUtf8 => "bad_utf8",
            CodecError::BadTag(..) => "bad_tag",
            CodecError::BadGeometry { .. } => "bad_geometry",
        }
    }
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Io(e) => write!(f, "io: {e}"),
            CodecError::BadMagic { expected, got } => {
                write!(f, "bad magic: expected {expected:#x}, got {got:#x}")
            }
            CodecError::BadVersion(v) => write!(f, "unsupported version {v}"),
            CodecError::TooLong(n, cap) => write!(f, "length {n} exceeds sanity limit {cap}"),
            CodecError::BadUtf8 => write!(f, "invalid utf-8 in string field"),
            CodecError::BadTag(t, what) => write!(f, "invalid enum tag {t} for {what}"),
            CodecError::BadGeometry { items, len, dim } => {
                write!(f, "bad batch geometry: {items} items x dim {dim} != {len} values")
            }
        }
    }
}

impl std::error::Error for CodecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CodecError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for CodecError {
    fn from(e: io::Error) -> CodecError {
        CodecError::Io(e)
    }
}

/// Sanity cap on decoded collection lengths (guards against corrupt files
/// / hostile peers allocating unbounded memory).
pub const MAX_LEN: u64 = 1 << 33; // 8 Gi elements

pub fn write_u8<W: Write>(w: &mut W, v: u8) -> io::Result<()> {
    w.write_all(&[v])
}

pub fn write_u32<W: Write>(w: &mut W, v: u32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn write_u64<W: Write>(w: &mut W, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn write_f32<W: Write>(w: &mut W, v: f32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn write_f64<W: Write>(w: &mut W, v: f64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn read_u8<R: Read>(r: &mut R) -> io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn read_f32<R: Read>(r: &mut R) -> io::Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

pub fn read_f64<R: Read>(r: &mut R) -> io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn checked_len<R: Read>(r: &mut R) -> Result<usize, CodecError> {
    let n = read_u64(r)?;
    if n > MAX_LEN {
        return Err(CodecError::TooLong(n, MAX_LEN));
    }
    Ok(n as usize)
}

pub fn write_string<W: Write>(w: &mut W, s: &str) -> io::Result<()> {
    write_u64(w, s.len() as u64)?;
    w.write_all(s.as_bytes())
}

pub fn read_string<R: Read>(r: &mut R) -> Result<String, CodecError> {
    let n = checked_len(r)?;
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|_| CodecError::BadUtf8)
}

/// Bulk f32 vector: length prefix + raw LE payload (single write).
pub fn write_f32_vec<W: Write>(w: &mut W, xs: &[f32]) -> io::Result<()> {
    write_u64(w, xs.len() as u64)?;
    // Byte-swap-free on LE targets; portable via per-element fallback on BE.
    #[cfg(target_endian = "little")]
    {
        let bytes =
            unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
        w.write_all(bytes)
    }
    #[cfg(target_endian = "big")]
    {
        for &x in xs {
            write_f32(w, x)?;
        }
        Ok(())
    }
}

pub fn read_f32_vec<R: Read>(r: &mut R) -> Result<Vec<f32>, CodecError> {
    let n = checked_len(r)?;
    let mut out = vec![0f32; n];
    #[cfg(target_endian = "little")]
    {
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, n * 4)
        };
        r.read_exact(bytes)?;
    }
    #[cfg(target_endian = "big")]
    {
        for slot in out.iter_mut() {
            *slot = read_f32(r)?;
        }
    }
    Ok(out)
}

pub fn write_u32_vec<W: Write>(w: &mut W, xs: &[u32]) -> io::Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        write_u32(w, x)?;
    }
    Ok(())
}

pub fn read_u32_vec<R: Read>(r: &mut R) -> Result<Vec<u32>, CodecError> {
    let n = checked_len(r)?;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        out.push(read_u32(r)?);
    }
    Ok(out)
}

pub fn write_u64_vec<W: Write>(w: &mut W, xs: &[u64]) -> io::Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        write_u64(w, x)?;
    }
    Ok(())
}

pub fn read_u64_vec<R: Read>(r: &mut R) -> Result<Vec<u64>, CodecError> {
    let n = checked_len(r)?;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        out.push(read_u64(r)?);
    }
    Ok(out)
}

/// Bit vector packed into u64 words: length-in-bits prefix + words.
pub fn write_bitvec<W: Write>(w: &mut W, bits: &[bool]) -> io::Result<()> {
    write_u64(w, bits.len() as u64)?;
    let words = bits.len().div_ceil(64);
    for wi in 0..words {
        let mut word = 0u64;
        for bi in 0..64 {
            let idx = wi * 64 + bi;
            if idx < bits.len() && bits[idx] {
                word |= 1 << bi;
            }
        }
        write_u64(w, word)?;
    }
    Ok(())
}

pub fn read_bitvec<R: Read>(r: &mut R) -> Result<Vec<bool>, CodecError> {
    let nbits = checked_len(r)?;
    let words = nbits.div_ceil(64);
    let mut out = Vec::with_capacity(nbits);
    for _ in 0..words {
        let word = read_u64(r)?;
        for bi in 0..64 {
            if out.len() < nbits {
                out.push(word & (1 << bi) != 0);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn scalar_roundtrips() {
        let mut buf = Vec::new();
        write_u8(&mut buf, 7).unwrap();
        write_u32(&mut buf, 0xDEAD_BEEF).unwrap();
        write_u64(&mut buf, u64::MAX - 3).unwrap();
        write_f32(&mut buf, -1.5).unwrap();
        write_f64(&mut buf, std::f64::consts::PI).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_u8(&mut c).unwrap(), 7);
        assert_eq!(read_u32(&mut c).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_u64(&mut c).unwrap(), u64::MAX - 3);
        assert_eq!(read_f32(&mut c).unwrap(), -1.5);
        assert_eq!(read_f64(&mut c).unwrap(), std::f64::consts::PI);
    }

    #[test]
    fn string_roundtrip() {
        let mut buf = Vec::new();
        write_string(&mut buf, "AHE-301-30c é").unwrap();
        let s = read_string(&mut Cursor::new(buf)).unwrap();
        assert_eq!(s, "AHE-301-30c é");
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.25 - 3.0).collect();
        let mut buf = Vec::new();
        write_f32_vec(&mut buf, &xs).unwrap();
        let ys = read_f32_vec(&mut Cursor::new(buf)).unwrap();
        assert_eq!(xs, ys);
    }

    #[test]
    fn int_vec_roundtrips() {
        let a: Vec<u32> = (0..257).collect();
        let b: Vec<u64> = (0..77).map(|i| i * 12345).collect();
        let mut buf = Vec::new();
        write_u32_vec(&mut buf, &a).unwrap();
        write_u64_vec(&mut buf, &b).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_u32_vec(&mut c).unwrap(), a);
        assert_eq!(read_u64_vec(&mut c).unwrap(), b);
    }

    #[test]
    fn bitvec_roundtrip_odd_lengths() {
        for n in [0usize, 1, 63, 64, 65, 130, 1000] {
            let bits: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            let mut buf = Vec::new();
            write_bitvec(&mut buf, &bits).unwrap();
            assert_eq!(read_bitvec(&mut Cursor::new(buf)).unwrap(), bits);
        }
    }

    #[test]
    fn truncated_input_errors() {
        let mut buf = Vec::new();
        write_f32_vec(&mut buf, &[1.0, 2.0, 3.0]).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_f32_vec(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn hostile_length_rejected() {
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX).unwrap();
        assert!(matches!(
            read_string(&mut Cursor::new(buf)),
            Err(CodecError::TooLong(..))
        ));
    }
}
