//! Structured parallelism helpers.
//!
//! No `rayon`/`tokio` in the offline environment, so DSLSH provides two
//! primitives built on `std::thread::scope`:
//!
//! * [`parallel_for`] — run a closure over index chunks on `t` threads;
//!   used for table construction and PKNN scans.
//! * [`parallel_map`] — map a closure over items, preserving order.
//!
//! The distributed runtime (`node/`, `coordinator/`) uses long-lived
//! threads with channels instead; these helpers cover the data-parallel
//! build phase where structure, not liveness, is needed.

/// Split `[0, len)` into `parts` contiguous ranges of near-equal size.
/// The first `len % parts` ranges get one extra element, matching the
/// paper's equal-shares data-parallel partitioning.
pub fn chunk_ranges(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    assert!(parts > 0, "chunk_ranges: parts == 0");
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    debug_assert_eq!(start, len);
    out
}

/// Run `f(thread_idx, range)` for each of `threads` contiguous chunks of
/// `[0, len)`, in parallel. Degenerates to an inline call for 1 thread.
pub fn parallel_for<F>(len: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1);
    let ranges = chunk_ranges(len, threads);
    if threads == 1 {
        f(0, ranges.into_iter().next().unwrap());
        return;
    }
    std::thread::scope(|scope| {
        for (i, r) in ranges.into_iter().enumerate() {
            let f = &f;
            scope.spawn(move || f(i, r));
        }
    });
}

/// Parallel map over `items` on up to `threads` threads; output order
/// matches input order.
pub fn parallel_map<T, U, F>(items: Vec<T>, threads: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<U>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    // Hand out items with their index through a locked iterator so uneven
    // work (e.g. LSH builds with different L) balances dynamically. The
    // queue lock hands each index to exactly one worker, so result writes
    // are disjoint by construction — workers write their slot through a
    // shared raw pointer instead of serializing behind a results mutex.
    let queue = std::sync::Mutex::new(items.into_iter().enumerate());
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let queue = &queue;
            let slots_ptr = &slots_ptr;
            let f = &f;
            scope.spawn(move || loop {
                let next = { queue.lock().unwrap().next() };
                match next {
                    Some((i, item)) => {
                        let out = f(item);
                        // SAFETY: i < n (enumerate over n items), each i is
                        // yielded once under the queue lock, and the scope
                        // joins all workers before `slots` is read again —
                        // no aliasing writes, no use-after-free. The old
                        // value is always `None`, so skipping its drop via
                        // `write` leaks nothing.
                        unsafe { slots_ptr.0.add(i).write(Some(out)) };
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker died")).collect()
}

/// Raw-pointer wrapper that asserts cross-thread shareability; sound here
/// because `parallel_map` guarantees disjoint writes and join-before-read.
struct SendPtr<U>(*mut U);

unsafe impl<U: Send> Send for SendPtr<U> {}
unsafe impl<U: Send> Sync for SendPtr<U> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_exactly() {
        for (len, parts) in [(10, 3), (0, 4), (7, 7), (7, 10), (100, 1)] {
            let ranges = chunk_ranges(len, parts);
            assert_eq!(ranges.len(), parts);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, len);
            // Contiguous and ordered.
            let mut pos = 0;
            for r in &ranges {
                assert_eq!(r.start, pos);
                pos = r.end;
            }
            // Near-equal: sizes differ by at most 1.
            let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn parallel_for_touches_every_index_once() {
        let n = 10_000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, 4, |_t, range| {
            for i in range {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(items, 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_disjoint_writes_with_owned_results() {
        // Heap-owning results + uneven per-item work: exercises the
        // raw-pointer disjoint-write path under real contention.
        let items: Vec<usize> = (0..500).collect();
        let out = parallel_map(items, 8, |x| {
            let mut s = String::new();
            for i in 0..(x % 17) {
                s.push_str(&i.to_string());
            }
            (x, s)
        });
        for (i, (x, s)) in out.iter().enumerate() {
            assert_eq!(*x, i);
            let expect: String = (0..(i % 17)).map(|v| v.to_string()).collect();
            assert_eq!(*s, expect);
        }
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        assert_eq!(parallel_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        assert_eq!(parallel_map(Vec::<i32>::new(), 4, |x| x), Vec::<i32>::new());
    }
}
