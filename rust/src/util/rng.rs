//! Deterministic pseudo-random number generation.
//!
//! The offline build environment ships no `rand` crate, so DSLSH carries its
//! own PRNG stack: [`SplitMix64`] for seeding and [`Xoshiro256`]
//! (xoshiro256++) as the workhorse generator. Both are well-studied, pass
//! BigCrush, and — crucially for a reproduction — give bit-identical
//! streams across platforms, so every experiment in EXPERIMENTS.md is
//! replayable from its seed.

/// SplitMix64: used to expand a single `u64` seed into the 256-bit state of
/// [`Xoshiro256`]. Also usable standalone for cheap hashing-style mixing.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output (Steele, Lea & Flood 2014 finalizer).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// One-shot stateless mix of a `u64` — handy for deriving stream ids.
#[inline]
pub fn mix64(x: u64) -> u64 {
    SplitMix64::new(x).next_u64()
}

/// xoshiro256++ 1.0 (Blackman & Vigna 2019).
///
/// 256 bits of state, period 2^256 − 1, sub-nanosecond generation. All
/// randomized components of DSLSH (hash families, waveform generator,
/// query sampling, bootstrap) draw from independent, seed-derived instances
/// of this generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors (avoids
    /// the all-zero state and decorrelates similar seeds).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Derive an independent child generator. Used to give every hash
    /// table / worker / dataset shard its own stream while staying fully
    /// reproducible from one experiment seed.
    pub fn fork(&mut self, stream: u64) -> Self {
        let base = self.next_u64();
        Self::seed_from_u64(base ^ mix64(stream.wrapping_add(0xA076_1D64_78BD_642F)))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` with 24 bits of precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn gen_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "gen_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi, "gen_range: empty range");
        lo + self.gen_below(hi - lo)
    }

    /// Uniform index into a slice of length `len`.
    #[inline]
    pub fn gen_index(&mut self, len: usize) -> usize {
        self.gen_below(len as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (polar form avoided: trig form is
    /// branch-free and accuracy is ample for data synthesis).
    #[inline]
    pub fn next_normal(&mut self) -> f64 {
        // u1 in (0, 1] so ln(u1) is finite.
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn gen_normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.next_normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small `k`, shuffle-prefix otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k > n");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        // Robert Floyd's sampling: O(k) expected, no O(n) allocation.
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_below(j as u64 + 1) as usize;
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference outputs for seed 1234567 from the public-domain C impl.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_is_deterministic_and_nonzero() {
        let mut r1 = Xoshiro256::seed_from_u64(42);
        let mut r2 = Xoshiro256::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::seed_from_u64(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3, "different seeds should decorrelate");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Xoshiro256::seed_from_u64(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let matches = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(matches, 0);
    }

    #[test]
    fn uniform_unit_interval_bounds_and_mean() {
        let mut r = Xoshiro256::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_below_is_in_range_and_roughly_uniform() {
        let mut r = Xoshiro256::seed_from_u64(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.gen_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn gen_range_respects_bounds() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.gen_range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(4);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Xoshiro256::seed_from_u64(6);
        for (n, k) in [(100, 5), (100, 50), (10, 10), (1000, 3)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut dedup = s.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn gen_bool_probability() {
        let mut r = Xoshiro256::seed_from_u64(8);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((28_000..32_000).contains(&hits), "hits={hits}");
    }
}
