//! Tiny leveled logger (no `log` facade or backend in the offline
//! environment, so we carry our own). Controlled by `DSLSH_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static START: OnceLock<Instant> = OnceLock::new();
static INIT: OnceLock<()> = OnceLock::new();

fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("DSLSH_LOG") {
            if let Some(l) = Level::parse(&v) {
                LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
        START.get_or_init(Instant::now);
    });
}

/// Set the level programmatically (overrides `DSLSH_LOG`).
pub fn set_level(level: Level) {
    init();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Core emit function used by the macros; writes a single line to stderr
/// with elapsed seconds, level and component tag.
pub fn emit(level: Level, component: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:10.3}s {} {component}] {args}", level.tag());
}

#[macro_export]
macro_rules! log_error {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Error, $comp, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Warn, $comp, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Info, $comp, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Debug, $comp, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Trace, $comp, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info); // restore default for other tests
    }
}
