//! Minimal JSON parser / writer.
//!
//! The offline environment carries no `serde`, so DSLSH implements the
//! small subset of JSON it needs for configs and experiment reports:
//! full RFC 8259 value grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null), preserved key order, and a pretty printer.
//!
//! Since the HTTP serving edge ([`crate::net::edge`]) feeds untrusted
//! request bodies through this parser, it is hardened against
//! adversarial input:
//!
//! * **Bounded recursion** — containers may nest at most [`MAX_DEPTH`]
//!   levels; a deep-nesting bomb is a parse error, not a stack overflow.
//! * **Strict RFC 8259 numbers** — leading zeros, `1.`, `.5`, `1e`,
//!   `NaN`/`Infinity` spellings and over-long exponents are all
//!   rejected, and any number that does not land on a *finite* `f64`
//!   (e.g. `1e400`) is an error, so `Json::Num` is finite by
//!   construction and round-trips through the writer.
//! * **Duplicate keys rejected** — two members with the same name in one
//!   object are a parse error (the classic smuggling vector where two
//!   layers disagree about which value wins). Programmatic
//!   [`JsonObj::insert`] keeps its last-write-wins contract.

use std::fmt;

/// Maximum container nesting the parser accepts. Deep enough for any
/// legitimate config or API body, shallow enough that parsing is
/// stack-safe on spawned threads.
pub const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Object keys keep insertion order via a Vec of
/// pairs plus an index for O(log n) lookup.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    pairs: Vec<(String, Json)>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if let Some(slot) = self.pairs.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = value;
        } else {
            self.pairs.push((key, value));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.pairs.iter().map(|(k, v)| (k, v))
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

/// Parse error with byte offset and message.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        match self.bump() {
            Some(b) if b == c => Ok(()),
            Some(b) => self.err(format!("expected '{}', got '{}'", c as char, b as char)),
            None => self.err(format!("expected '{}', got EOF", c as char)),
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected character '{}'", c as char)),
            None => self.err("unexpected EOF"),
        }
    }

    fn keyword(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            self.err(format!("invalid literal, expected '{word}'"))
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        if self.depth >= MAX_DEPTH {
            return self.err(format!("nesting deeper than {MAX_DEPTH} levels"));
        }
        self.depth += 1;
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if obj.get(&key).is_some() {
                return self.err(format!("duplicate key {key:?}"));
            }
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("expected low surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(b) if b < 0x20 => return self.err("control character in string"),
                Some(b) => {
                    // Reassemble UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    if len == 0 || start + len > self.bytes.len() {
                        return self.err("invalid utf-8");
                    }
                    self.pos = start + len;
                    match std::str::from_utf8(&self.bytes[start..start + len]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a') as u32 + 10,
                Some(c @ b'A'..=b'F') => (c - b'A') as u32 + 10,
                _ => return self.err("invalid \\u escape"),
            };
            v = (v << 4) | d;
        }
        Ok(v)
    }

    /// Strict RFC 8259 number grammar, plus a finiteness requirement:
    /// every accepted number is a finite `f64`, so values round-trip
    /// through the writer and downstream code never sees NaN/Inf.
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let int_start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let int_len = self.pos - int_start;
        if int_len == 0 {
            return self.err("number must have integer digits");
        }
        if int_len > 1 && self.bytes[int_start] == b'0' {
            return self.err("leading zeros are not allowed");
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            let frac_start = self.pos;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
            if self.pos == frac_start {
                return self.err("digit required after decimal point");
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            let exp_start = self.pos;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
            let exp_len = self.pos - exp_start;
            if exp_len == 0 {
                return self.err("digit required in exponent");
            }
            // f64 tops out around e±308; anything longer is hostile.
            if exp_len > 4 {
                return self.err("exponent too large");
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            Ok(_) => self.err(format!("number '{text}' overflows f64")),
            Err(_) => self.err(format!("invalid number '{text}'")),
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 0,
    }
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return p.err("trailing characters");
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj.get(key)` sugar that tunnels through to `None` on type error.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- construction sugar ---------------------------------------------

    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(obj) => {
                if obj.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in obj.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Json {
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": {"d": "x"}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld ≤\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ≤");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"name":"dslsh","params":{"m":125,"L":120,"alpha":0.005},"grid":[1,2.5,-3],"ok":true,"none":null}"#;
        let v = Json::parse(doc).unwrap();
        let compact = v.to_string_compact();
        let v2 = Json::parse(&compact).unwrap();
        assert_eq!(v, v2);
        let pretty = v.to_string_pretty();
        let v3 = Json::parse(&pretty).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn object_preserves_order_and_overwrites() {
        let mut o = JsonObj::new();
        o.insert("z", Json::Num(1.0));
        o.insert("a", Json::Num(2.0));
        o.insert("z", Json::Num(3.0));
        let keys: Vec<&str> = o.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a"]);
        assert_eq!(o.get("z").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "f": 1.5, "s": "x", "b": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::Num(120.0).to_string_compact(), "120");
        assert_eq!(Json::Num(0.005).to_string_compact(), "0.005");
    }

    #[test]
    fn nesting_is_bounded() {
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&ok).is_ok());
        let too_deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        let e = Json::parse(&too_deep).unwrap_err();
        assert!(e.msg.contains("nesting"), "{e}");
        // Objects count against the same budget as arrays.
        let obj_ok = "{\"a\":".repeat(MAX_DEPTH) + "1" + &"}".repeat(MAX_DEPTH);
        assert!(Json::parse(&obj_ok).is_ok());
        let obj_deep = "{\"a\":".repeat(MAX_DEPTH + 1) + "1" + &"}".repeat(MAX_DEPTH + 1);
        assert!(Json::parse(&obj_deep).is_err());
    }

    #[test]
    fn non_finite_and_huge_exponents_are_rejected() {
        for bad in ["NaN", "Infinity", "-Infinity", "nan", "inf"] {
            assert!(Json::parse(bad).is_err(), "{bad} must not parse");
        }
        for bad in ["1e400", "-1e309", "1e99999", "2.5e+999999999"] {
            assert!(Json::parse(bad).is_err(), "{bad} must not parse to ±inf");
        }
        // Large but finite is fine.
        assert_eq!(Json::parse("1e308").unwrap(), Json::Num(1e308));
        assert_eq!(Json::parse("-2.5e-300").unwrap(), Json::Num(-2.5e-300));
    }

    #[test]
    fn strict_number_grammar() {
        for bad in ["01", "-01", "1.", ".5", "-.5", "1e", "1e+", "+1", "0x10", "1_000", "--1"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must be rejected");
        }
        for good in ["0", "-0", "0.5", "10", "1e3", "1E-3", "1.25e+2"] {
            assert!(Json::parse(good).is_ok(), "{good:?} must parse");
        }
    }

    #[test]
    fn duplicate_keys_are_a_parse_error() {
        let e = Json::parse(r#"{"a":1,"a":2}"#).unwrap_err();
        assert!(e.msg.contains("duplicate"), "{e}");
        // Same name at different nesting levels is legitimate.
        assert!(Json::parse(r#"{"a":{"a":1},"b":[{"a":2}]}"#).is_ok());
    }

    /// Seeded random documents survive parse → print → parse with `==`
    /// (possible because every accepted number is a finite f64 and the
    /// writer's `{}` formatting is shortest-roundtrip).
    #[test]
    fn parse_print_parse_roundtrip_property() {
        use crate::util::rng::Xoshiro256;

        fn gen_value(rng: &mut Xoshiro256, depth: usize) -> Json {
            let pick = if depth >= 5 { rng.gen_below(4) } else { rng.gen_below(6) };
            match pick {
                0 => Json::Null,
                1 => Json::Bool(rng.gen_bool(0.5)),
                2 => {
                    // Mix integers, f32-ish and full-precision doubles.
                    match rng.gen_below(3) {
                        0 => Json::Num(rng.gen_range(0, 1 << 20) as f64 - 1e5),
                        1 => Json::Num(f64::from(rng.next_f32()) * 100.0),
                        _ => Json::Num(rng.gen_f64(-1e12, 1e12)),
                    }
                }
                3 => {
                    let len = rng.gen_below(8) as usize;
                    Json::Str(
                        (0..len)
                            .map(|_| {
                                // Printable ASCII plus escapes plus multibyte.
                                match rng.gen_below(4) {
                                    0 => '"',
                                    1 => '\\',
                                    2 => 'é',
                                    _ => (b'a' + rng.gen_below(26) as u8) as char,
                                }
                            })
                            .collect(),
                    )
                }
                4 => {
                    let len = rng.gen_below(4) as usize;
                    Json::Arr((0..len).map(|_| gen_value(rng, depth + 1)).collect())
                }
                _ => {
                    let len = rng.gen_below(4) as usize;
                    let mut o = JsonObj::new();
                    for i in 0..len {
                        o.insert(format!("k{i}"), gen_value(rng, depth + 1));
                    }
                    Json::Obj(o)
                }
            }
        }

        let mut rng = Xoshiro256::seed_from_u64(0x150_4a50);
        for round in 0..200 {
            let doc = gen_value(&mut rng, 0);
            let compact = doc.to_string_compact();
            let back = Json::parse(&compact).unwrap_or_else(|e| {
                panic!("round {round}: reparse failed on {compact:?}: {e}")
            });
            assert_eq!(doc, back, "round {round}: {compact}");
            let pretty = doc.to_string_pretty();
            assert_eq!(doc, Json::parse(&pretty).unwrap(), "round {round} (pretty)");
        }
    }
}
