//! Minimal JSON parser / writer.
//!
//! The offline environment carries no `serde`, so DSLSH implements the
//! small subset of JSON it needs for configs and experiment reports:
//! full RFC 8259 value grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null), preserved key order, and a pretty printer.

use std::fmt;

/// A parsed JSON value. Object keys keep insertion order via a Vec of
/// pairs plus an index for O(log n) lookup.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    pairs: Vec<(String, Json)>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if let Some(slot) = self.pairs.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = value;
        } else {
            self.pairs.push((key, value));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.pairs.iter().map(|(k, v)| (k, v))
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

/// Parse error with byte offset and message.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        match self.bump() {
            Some(b) if b == c => Ok(()),
            Some(b) => self.err(format!("expected '{}', got '{}'", c as char, b as char)),
            None => self.err(format!("expected '{}', got EOF", c as char)),
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected character '{}'", c as char)),
            None => self.err("unexpected EOF"),
        }
    }

    fn keyword(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            self.err(format!("invalid literal, expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("expected low surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(b) if b < 0x20 => return self.err("control character in string"),
                Some(b) => {
                    // Reassemble UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    if len == 0 || start + len > self.bytes.len() {
                        return self.err("invalid utf-8");
                    }
                    self.pos = start + len;
                    match std::str::from_utf8(&self.bytes[start..start + len]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a') as u32 + 10,
                Some(c @ b'A'..=b'F') => (c - b'A') as u32 + 10,
                _ => return self.err("invalid \\u escape"),
            };
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err(format!("invalid number '{text}'")),
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 0,
    }
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return p.err("trailing characters");
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj.get(key)` sugar that tunnels through to `None` on type error.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- construction sugar ---------------------------------------------

    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(obj) => {
                if obj.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in obj.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Json {
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": {"d": "x"}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld ≤\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ≤");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"name":"dslsh","params":{"m":125,"L":120,"alpha":0.005},"grid":[1,2.5,-3],"ok":true,"none":null}"#;
        let v = Json::parse(doc).unwrap();
        let compact = v.to_string_compact();
        let v2 = Json::parse(&compact).unwrap();
        assert_eq!(v, v2);
        let pretty = v.to_string_pretty();
        let v3 = Json::parse(&pretty).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn object_preserves_order_and_overwrites() {
        let mut o = JsonObj::new();
        o.insert("z", Json::Num(1.0));
        o.insert("a", Json::Num(2.0));
        o.insert("z", Json::Num(3.0));
        let keys: Vec<&str> = o.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a"]);
        assert_eq!(o.get("z").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "f": 1.5, "s": "x", "b": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::Num(120.0).to_string_compact(), "120");
        assert_eq!(Json::Num(0.005).to_string_compact(), "0.005");
    }
}
