//! Stamped visited-set: O(1) insert/test, O(1) clear between queries.
//!
//! The candidate-union step must deduplicate ids across `L` tables for
//! every query; a `HashSet` would allocate and hash on the hot path, a
//! `Vec<bool>` would need an O(n) clear per query. A stamp array does
//! both in O(1): clearing is a single epoch increment.

#[derive(Debug, Clone)]
pub struct StampSet {
    stamps: Vec<u32>,
    epoch: u32,
}

impl StampSet {
    pub fn new(capacity: usize) -> Self {
        Self { stamps: vec![0; capacity], epoch: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.stamps.len()
    }

    /// Grow to at least `capacity` slots (no-op when already large
    /// enough). New slots carry stamp 0, which is never a live epoch
    /// after [`clear`] has run, so existing marks stay valid.
    ///
    /// [`clear`]: StampSet::clear
    pub fn ensure_capacity(&mut self, capacity: usize) {
        if self.stamps.len() < capacity {
            self.stamps.resize(capacity, 0);
        }
    }

    /// Start a new query: invalidates all marks in O(1) (with a rare O(n)
    /// reset when the 32-bit epoch wraps).
    pub fn clear(&mut self) {
        if self.epoch == u32::MAX {
            self.stamps.fill(0);
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    /// Mark `id`; returns true iff it was NOT already marked this epoch.
    #[inline]
    pub fn insert(&mut self, id: u32) -> bool {
        let slot = &mut self.stamps[id as usize];
        if *slot == self.epoch {
            false
        } else {
            *slot = self.epoch;
            true
        }
    }

    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        self.stamps[id as usize] == self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_dedup() {
        let mut s = StampSet::new(10);
        s.clear();
        assert!(s.insert(3));
        assert!(!s.insert(3));
        assert!(s.contains(3));
        assert!(!s.contains(4));
    }

    #[test]
    fn clear_invalidates_previous_epoch() {
        let mut s = StampSet::new(5);
        s.clear();
        s.insert(1);
        s.insert(2);
        s.clear();
        assert!(!s.contains(1));
        assert!(!s.contains(2));
        assert!(s.insert(1));
    }

    #[test]
    fn fresh_set_marks_nothing() {
        let mut s = StampSet::new(4);
        s.clear();
        for i in 0..4 {
            assert!(!s.contains(i));
        }
    }

    #[test]
    fn ensure_capacity_grows_and_keeps_marks() {
        let mut s = StampSet::new(4);
        s.clear();
        s.insert(3);
        s.ensure_capacity(10);
        assert_eq!(s.capacity(), 10);
        assert!(s.contains(3), "existing marks survive growth");
        assert!(!s.contains(9));
        assert!(s.insert(9));
        s.ensure_capacity(2); // never shrinks
        assert_eq!(s.capacity(), 10);
    }

    #[test]
    fn epoch_wrap_resets_correctly() {
        let mut s = StampSet::new(3);
        s.epoch = u32::MAX - 1;
        s.clear(); // -> MAX
        s.insert(0);
        assert!(s.contains(0));
        s.clear(); // wrap: full reset then epoch 1
        assert!(!s.contains(0));
        assert!(s.insert(0));
    }
}
