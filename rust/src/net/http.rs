//! Zero-dependency HTTP/1.1 front-door codec: a bounded, injectable-clock
//! request parser and a response writer, used by the serving edge
//! ([`crate::net::edge`]) in front of the Orchestrator.
//!
//! This is the one place untrusted bytes from arbitrary clients enter the
//! system, so the parser is written to the same discipline as the binary
//! wire codec ([`crate::net::wire`]): every malformed, truncated,
//! oversized or stalled input is a typed error — never a panic, never a
//! hang, never a silent partial parse. Specifically:
//!
//! * **Bounded everything.** Request line + headers are capped
//!   ([`Limits::max_head`], [`Limits::max_headers`]), the body by
//!   [`Limits::max_body`]; exceeding a cap is an error, not an
//!   allocation.
//! * **Clock-injected read deadline.** The parser polls a non-blocking
//!   (read-timeout) stream and checks an injected
//!   [`Clock`](crate::util::clock::Clock) against a deadline on every
//!   would-block, so a slowloris client is cut off deterministically —
//!   tests drive the timeout with a `MockClock`, production with
//!   `SystemClock` (no real sleeps in either).
//! * **Smuggling-hostile.** Duplicate or malformed `Content-Length`,
//!   any `Transfer-Encoding`, control bytes in header names/values
//!   (CR/LF injection) and obs-folded continuation lines are all
//!   rejected outright; the edge speaks one-request-per-connection
//!   (`Connection: close`), so there is no pipeline to desynchronize.
//!
//! # Status-code ↔ cluster-semantics contract
//!
//! The serving edge maps cluster outcomes onto HTTP like this (the
//! routing half lives in [`crate::net::edge`]; the table is the API
//! contract):
//!
//! | status | meaning at the cluster |
//! |--------|------------------------|
//! | `200`  | complete answer: every shard contributed a full scan |
//! | `206`  | budget-blown or degraded answer: `QueryResult::partial` — a table-prefix answer, `shed_nodes` shards contributed nothing |
//! | `400`  | malformed HTTP or JSON, schema violation, wrong dimension |
//! | `404`  | unknown path |
//! | `405`  | known path, wrong method (`Allow` header lists the right one) |
//! | `408`  | request read deadline expired (slowloris cut-off) |
//! | `411`  | `POST` without `Content-Length` |
//! | `413`  | declared body exceeds [`Limits::max_body`] |
//! | `429`  | admission queue full (`AdmissionError::QueueFull`) — backpressure, `Retry-After` tells the client when to come back |
//! | `431`  | request line + headers exceed [`Limits::max_head`] / [`Limits::max_headers`] |
//! | `503`  | cluster shutting down, zero-ack insert (`ShardUnavailable`), or `/readyz` with a replica down |
//! | `505`  | HTTP version other than 1.0/1.1 |
//!
//! Every non-2xx body is typed JSON: `{"error":{"code":..,"message":..}}`.

use std::io::{Read, Write};

use crate::util::clock::Clock;

/// Hard caps on what one request may cost before it is rejected.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Max bytes of request line + headers (terminator included).
    pub max_head: usize,
    /// Max number of header fields.
    pub max_headers: usize,
    /// Max declared (and read) body bytes.
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits { max_head: 16 * 1024, max_headers: 64, max_body: 1 << 20 }
    }
}

/// A typed request-handling failure: the HTTP status it maps to, a
/// stable machine-readable code and a human-readable message. The edge
/// serializes it as the `{"error":{...}}` body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub code: &'static str,
    pub msg: String,
}

impl HttpError {
    pub fn new(status: u16, code: &'static str, msg: impl Into<String>) -> HttpError {
        HttpError { status, code, msg: msg.into() }
    }

    fn bad(code: &'static str, msg: impl Into<String>) -> HttpError {
        HttpError::new(400, code, msg)
    }

    /// The typed JSON error body for this failure.
    pub fn body(&self) -> String {
        error_body(self.code, &self.msg)
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}: {}", self.status, self.code, self.msg)
    }
}

impl std::error::Error for HttpError {}

/// Build the canonical typed error body.
pub fn error_body(code: &str, msg: &str) -> String {
    use crate::util::json::{Json, JsonObj};
    let mut err = JsonObj::new();
    err.insert("code", Json::Str(code.to_string()));
    err.insert("message", Json::Str(msg.to_string()));
    let mut top = JsonObj::new();
    top.insert("error", Json::Obj(err));
    Json::Obj(top).to_string_compact()
}

/// Reason phrase for the status codes the edge emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        206 => "Partial Content",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// One parsed request. Headers keep arrival order with original names;
/// lookup is case-insensitive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query string (after `?`), if any.
    pub query: Option<String>,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of `name`, case-insensitive.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Incremental reader: accumulates bytes from a (possibly read-timeout)
/// stream, converting would-block into a deadline check against the
/// injected clock.
struct Source<'a, R: Read> {
    r: &'a mut R,
    clock: &'a dyn Clock,
    deadline_ns: u64,
    buf: Vec<u8>,
    eof: bool,
}

impl<R: Read> Source<'_, R> {
    /// Pull at least one more byte into `buf` (or learn EOF). A stalled
    /// stream (WouldBlock / TimedOut) re-polls until the deadline.
    fn fill(&mut self) -> Result<(), HttpError> {
        if self.eof {
            return Ok(());
        }
        let mut chunk = [0u8; 4096];
        loop {
            match self.r.read(&mut chunk) {
                Ok(0) => {
                    self.eof = true;
                    return Ok(());
                }
                Ok(n) => {
                    self.buf.extend_from_slice(&chunk[..n]);
                    return Ok(());
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    if self.clock.now_ns() >= self.deadline_ns {
                        return Err(HttpError::new(
                            408,
                            "timeout",
                            "request not completed within the read deadline",
                        ));
                    }
                }
                Err(e) => {
                    return Err(HttpError::bad("read-error", format!("stream error: {e}")));
                }
            }
        }
    }
}

/// Parse one HTTP/1.1 request from `r`, enforcing `limits` and the read
/// deadline `deadline_ns` (checked on `clock` whenever the stream
/// stalls). Every failure is a typed [`HttpError`]; a truncated request
/// (EOF mid-head or mid-body) is an error, never a partial success —
/// the truncation-at-every-byte property tests pin exactly that.
pub fn parse_request<R: Read>(
    r: &mut R,
    clock: &dyn Clock,
    deadline_ns: u64,
    limits: &Limits,
) -> Result<Request, HttpError> {
    let mut src = Source { r, clock, deadline_ns, buf: Vec::new(), eof: false };

    // --- head: everything up to the blank line -------------------------
    let head_end = loop {
        if let Some(at) = find_terminator(&src.buf) {
            break at;
        }
        if src.buf.len() > limits.max_head {
            return Err(HttpError::new(
                431,
                "head-too-large",
                format!("request head exceeds {} bytes", limits.max_head),
            ));
        }
        if src.eof {
            return Err(HttpError::bad("truncated-request", "EOF before end of headers"));
        }
        src.fill()?;
    };
    if head_end + 4 > limits.max_head {
        return Err(HttpError::new(
            431,
            "head-too-large",
            format!("request head exceeds {} bytes", limits.max_head),
        ));
    }

    let head = src.buf[..head_end].to_vec();
    let mut lines = split_crlf(&head)?;
    if lines.is_empty() {
        return Err(HttpError::bad("empty-request", "missing request line"));
    }
    let (method, path, query) = parse_request_line(&lines.remove(0))?;
    if lines.len() > limits.max_headers {
        return Err(HttpError::new(
            431,
            "too-many-headers",
            format!("more than {} header fields", limits.max_headers),
        ));
    }
    let mut headers = Vec::with_capacity(lines.len());
    for line in &lines {
        headers.push(parse_header(line)?);
    }

    // --- framing: Content-Length only, exactly once --------------------
    if headers.iter().any(|(k, _)| k.eq_ignore_ascii_case("transfer-encoding")) {
        return Err(HttpError::bad(
            "transfer-encoding-unsupported",
            "Transfer-Encoding is not accepted; use Content-Length",
        ));
    }
    let cls: Vec<&str> = headers
        .iter()
        .filter(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.as_str())
        .collect();
    let content_length = match cls.len() {
        0 => None,
        1 => Some(parse_content_length(cls[0], limits)?),
        _ => {
            return Err(HttpError::bad(
                "duplicate-content-length",
                "multiple Content-Length headers",
            ))
        }
    };
    let content_length = match (content_length, method.as_str()) {
        (Some(n), _) => n,
        (None, "POST" | "PUT" | "PATCH") => {
            return Err(HttpError::new(
                411,
                "length-required",
                "POST requires a Content-Length header",
            ))
        }
        (None, _) => 0,
    };

    // --- body: exactly Content-Length bytes ----------------------------
    let body_start = head_end + 4;
    while src.buf.len() < body_start + content_length {
        if src.eof {
            return Err(HttpError::bad(
                "truncated-body",
                format!(
                    "EOF after {} of {} declared body bytes",
                    src.buf.len().saturating_sub(body_start),
                    content_length
                ),
            ));
        }
        src.fill()?;
    }
    // Trailing bytes beyond Content-Length are a framing violation under
    // one-request-per-connection: there is no next request to own them.
    if src.buf.len() > body_start + content_length {
        return Err(HttpError::bad("excess-body", "bytes beyond the declared Content-Length"));
    }
    let body = src.buf[body_start..body_start + content_length].to_vec();

    Ok(Request { method, path, query, headers, body })
}

/// Index of the `\r\n\r\n` head terminator, if present.
fn find_terminator(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Split the head into lines on CRLF. Any bare CR or bare LF left inside
/// a line is rejected (header-injection hostile), as are obs-fold
/// continuations (a line starting with SP/HT). The head never ends with
/// a CRLF of its own — the terminating `\r\n\r\n` was cut off before it.
fn split_crlf(head: &[u8]) -> Result<Vec<Vec<u8>>, HttpError> {
    let mut lines = Vec::new();
    let mut rest = head;
    loop {
        match rest.windows(2).position(|w| w == b"\r\n") {
            Some(i) => {
                lines.push(rest[..i].to_vec());
                rest = &rest[i + 2..];
            }
            None => {
                lines.push(rest.to_vec());
                break;
            }
        }
    }
    for line in &lines {
        if line.contains(&b'\r') {
            return Err(HttpError::bad("bare-cr", "bare CR in request head"));
        }
        if line.contains(&b'\n') {
            return Err(HttpError::bad("bare-lf", "bare LF in request head"));
        }
        if matches!(line.first(), Some(b' ' | b'\t')) {
            return Err(HttpError::bad("obs-fold", "folded header continuation lines"));
        }
    }
    Ok(lines)
}

fn parse_request_line(line: &[u8]) -> Result<(String, String, Option<String>), HttpError> {
    let parts: Vec<&[u8]> = line.split(|&b| b == b' ').collect();
    if parts.len() != 3 || parts.iter().any(|p| p.is_empty()) {
        return Err(HttpError::bad("bad-request-line", "expected 'METHOD target HTTP/x.y'"));
    }
    let method = parts[0];
    if !method.iter().all(|&b| b.is_ascii_uppercase()) {
        return Err(HttpError::bad("bad-method", "method must be upper-case ASCII"));
    }
    let target = parts[1];
    if target[0] != b'/' || !target.iter().all(|&b| (0x21..=0x7e).contains(&b)) {
        return Err(HttpError::bad("bad-target", "target must be a visible-ASCII absolute path"));
    }
    match parts[2] {
        b"HTTP/1.1" | b"HTTP/1.0" => {}
        v if v.starts_with(b"HTTP/") => {
            return Err(HttpError::new(505, "bad-version", "only HTTP/1.0 and HTTP/1.1"))
        }
        _ => return Err(HttpError::bad("bad-request-line", "malformed HTTP version")),
    }
    let target = String::from_utf8(target.to_vec())
        .map_err(|_| HttpError::bad("bad-target", "non-UTF-8 target"))?;
    let (path, qstr) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target, None),
    };
    Ok((String::from_utf8(method.to_vec()).unwrap(), path, qstr))
}

/// RFC 7230 `tchar` — the bytes legal in a header field name.
fn is_tchar(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b"!#$%&'*+-.^_`|~".contains(&b)
}

fn parse_header(line: &[u8]) -> Result<(String, String), HttpError> {
    let colon = line
        .iter()
        .position(|&b| b == b':')
        .ok_or_else(|| HttpError::bad("bad-header", "header line without ':'"))?;
    let (name, rest) = line.split_at(colon);
    if name.is_empty() || !name.iter().all(|&b| is_tchar(b)) {
        return Err(HttpError::bad("bad-header-name", "invalid header field name"));
    }
    let value = &rest[1..];
    let value = trim_ows(value);
    if !value.iter().all(|&b| b == b'\t' || (0x20..=0x7e).contains(&b)) {
        return Err(HttpError::bad("bad-header-value", "control bytes in header value"));
    }
    Ok((
        String::from_utf8(name.to_vec()).unwrap(),
        String::from_utf8(value.to_vec()).unwrap(),
    ))
}

fn trim_ows(v: &[u8]) -> &[u8] {
    let start = v.iter().position(|&b| b != b' ' && b != b'\t').unwrap_or(v.len());
    let end = v.iter().rposition(|&b| b != b' ' && b != b'\t').map(|i| i + 1).unwrap_or(start);
    &v[start..end]
}

fn parse_content_length(v: &str, limits: &Limits) -> Result<usize, HttpError> {
    if v.is_empty() || v.len() > 18 || !v.bytes().all(|b| b.is_ascii_digit()) {
        return Err(HttpError::bad("bad-content-length", "Content-Length must be decimal digits"));
    }
    let n: u64 = v.parse().expect("digits only");
    if n as usize > limits.max_body {
        return Err(HttpError::new(
            413,
            "body-too-large",
            format!("declared body of {n} bytes exceeds the {} byte cap", limits.max_body),
        ));
    }
    Ok(n as usize)
}

/// One HTTP response. The writer always emits `Content-Length`,
/// `Content-Type` and `Connection: close` — the edge speaks one request
/// per connection, so clients frame on close and a desynchronized parse
/// cannot leak into a second request. Bodies are JSON everywhere except
/// `GET /metrics`, which speaks the Prometheus text exposition format.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
    pub content_type: &'static str,
}

impl Response {
    /// A JSON response with the given status.
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: body.into(),
            content_type: "application/json",
        }
    }

    /// A Prometheus text-exposition response (`GET /metrics`). The
    /// `version=0.0.4` parameter is the scrape format version Prometheus
    /// content-negotiates on, not this crate's version.
    pub fn metrics_text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: body.into(),
            content_type: "text/plain; version=0.0.4",
        }
    }

    /// The canonical typed error response.
    pub fn error(status: u16, code: &'static str, msg: &str) -> Response {
        Response::json(status, error_body(code, msg))
    }

    /// From a parser/validation failure.
    pub fn from_err(e: &HttpError) -> Response {
        Response::json(e.status, e.body())
    }

    pub fn with_header(mut self, name: impl Into<String>, value: impl Into<String>) -> Response {
        self.headers.push((name.into(), value.into()));
        self
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, reason(self.status))?;
        write!(w, "Content-Type: {}\r\n", self.content_type)?;
        write!(w, "Content-Length: {}\r\n", self.body.len())?;
        write!(w, "Connection: close\r\n")?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "\r\n")?;
        w.write_all(self.body.as_bytes())?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::MockClock;
    use std::io::Cursor;

    fn parse(bytes: &[u8]) -> Result<Request, HttpError> {
        let clock = MockClock::new(0);
        parse_request(&mut Cursor::new(bytes), &clock, u64::MAX, &Limits::default())
    }

    #[test]
    fn parses_get_and_post() {
        let r = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.body.is_empty());

        let r = parse(b"POST /v1/query?trace=1 HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}").unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/query");
        assert_eq!(r.query.as_deref(), Some("trace=1"));
        assert_eq!(r.body, b"{}");
    }

    #[test]
    fn header_lookup_is_case_insensitive_and_ows_trimmed() {
        let r = parse(b"GET / HTTP/1.1\r\nX-Thing:   padded \t\r\n\r\n").unwrap();
        assert_eq!(r.header("x-thing"), Some("padded"));
        assert_eq!(r.header("X-THING"), Some("padded"));
    }

    #[test]
    fn post_without_content_length_is_411() {
        let e = parse(b"POST /v1/query HTTP/1.1\r\nHost: x\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 411);
    }

    #[test]
    fn duplicate_content_length_is_rejected() {
        let e = parse(b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\n{}")
            .unwrap_err();
        assert_eq!((e.status, e.code), (400, "duplicate-content-length"));
    }

    #[test]
    fn transfer_encoding_is_rejected() {
        let e = parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert_eq!((e.status, e.code), (400, "transfer-encoding-unsupported"));
    }

    #[test]
    fn oversized_declared_body_is_413() {
        let limits = Limits { max_body: 64, ..Limits::default() };
        let clock = MockClock::new(0);
        let req = b"POST / HTTP/1.1\r\nContent-Length: 65\r\n\r\n";
        let e = parse_request(&mut Cursor::new(&req[..]), &clock, u64::MAX, &limits).unwrap_err();
        assert_eq!(e.status, 413);
    }

    #[test]
    fn oversized_head_is_431() {
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        req.extend_from_slice(format!("X-Pad: {}\r\n\r\n", "a".repeat(32 * 1024)).as_bytes());
        let e = parse(&req).unwrap_err();
        assert_eq!(e.status, 431);
    }

    #[test]
    fn header_injection_and_folding_are_rejected() {
        // Bare CR inside a header line.
        assert_eq!(parse(b"GET / HTTP/1.1\r\nX-A: a\rb\r\n\r\n").unwrap_err().code, "bare-cr");
        // Bare LF line termination.
        assert_eq!(parse(b"GET / HTTP/1.1\nHost: x\r\n\r\n").unwrap_err().code, "bare-lf");
        // Obsolete folded continuation.
        assert_eq!(parse(b"GET / HTTP/1.1\r\nX-A: a\r\n b\r\n\r\n").unwrap_err().code, "obs-fold");
        // Control byte in a header value.
        assert_eq!(
            parse(b"GET / HTTP/1.1\r\nX-A: a\x01b\r\n\r\n").unwrap_err().code,
            "bad-header-value"
        );
        // Space in a header name.
        assert_eq!(
            parse(b"GET / HTTP/1.1\r\nX A: b\r\n\r\n").unwrap_err().code,
            "bad-header-name"
        );
    }

    #[test]
    fn truncation_at_every_byte_is_an_error() {
        let full: &[u8] = b"POST /v1/query HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"point\":[1]}";
        assert!(parse(full).is_ok());
        for cut in 0..full.len() {
            assert!(parse(&full[..cut]).is_err(), "prefix of {cut} bytes must not parse");
        }
    }

    #[test]
    fn excess_body_bytes_are_rejected() {
        let e = parse(b"POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}garbage").unwrap_err();
        assert_eq!(e.code, "excess-body");
    }

    #[test]
    fn bad_versions_and_methods() {
        assert_eq!(parse(b"GET / HTTP/2.0\r\n\r\n").unwrap_err().status, 505);
        assert_eq!(parse(b"get / HTTP/1.1\r\n\r\n").unwrap_err().code, "bad-method");
        assert_eq!(parse(b"GET  / HTTP/1.1\r\n\r\n").unwrap_err().code, "bad-request-line");
        assert_eq!(parse(b"GET x HTTP/1.1\r\n\r\n").unwrap_err().code, "bad-target");
    }

    /// A stream that never yields bytes, only would-block — each poll
    /// advances the MockClock, so the deadline passes after a
    /// deterministic number of polls (a slowloris in miniature).
    struct Stalled<'a> {
        clock: &'a MockClock,
        step_ns: u64,
    }

    impl Read for Stalled<'_> {
        fn read(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
            self.clock.advance_ns(self.step_ns);
            Err(std::io::Error::from(std::io::ErrorKind::WouldBlock))
        }
    }

    #[test]
    fn stalled_stream_times_out_on_the_injected_clock() {
        let clock = MockClock::new(0);
        let mut r = Stalled { clock: &clock, step_ns: 400_000 };
        let e = parse_request(&mut r, &clock, 1_000_000, &Limits::default()).unwrap_err();
        assert_eq!((e.status, e.code), (408, "timeout"));
        // 400µs per poll against a 1ms deadline: exactly 3 polls.
        assert_eq!(clock.now_ns(), 1_200_000);
    }

    #[test]
    fn response_writer_emits_framing_headers() {
        let mut out = Vec::new();
        Response::json(429, error_body("queue-full", "try later"))
            .with_header("Retry-After", "1")
            .write_to(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Content-Length: "));
        assert!(text.ends_with("\"message\":\"try later\"}}"));
    }
}
