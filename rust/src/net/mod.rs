//! TCP transport — the cloud-deployment path.
//!
//! In the paper's deployment the Orchestrator and the ν SLSH nodes are
//! separate cloud VMs. This module provides that wire path: a
//! length-prefixed binary protocol ([`wire`]), a node server
//! ([`serve_node`] / [`serve_node_loop`]) run by `dslsh serve-node`, and
//! a [`RemoteNode`] client implementing
//! [`NodeHandle`](crate::coordinator::NodeHandle) so the Orchestrator
//! drives remote processes exactly like in-process nodes.
//!
//! # Failure-semantics contract
//!
//! The transport's promise to the coordination layer above it:
//!
//! 1. **Faults are values, never panics.** Every [`RemoteNode`] request
//!    returns `Result<_, NodeError>`; a write error, read error,
//!    mid-frame EOF or protocol desync (wrong frame type, out-of-order
//!    reply) is an `Err`, not an abort. The process never dies because a
//!    peer did.
//! 2. **A fault poisons the connection.** After any transport error the
//!    frame boundary is unknowable, so the handle drops its stream and
//!    every later request fails fast ("connection is down") instead of
//!    reading garbage. Recovery is explicit:
//!    [`NodeHandle::reconnect`](crate::coordinator::NodeHandle) re-dials
//!    and replays the retained build frame — batch shards rebuild
//!    bit-identically from the same seed and bytes; live nodes come back
//!    empty (re-population belongs to the replicated orchestrator).
//! 3. **Hostile input is rejected at the boundary.** Both directions
//!    validate peer-controlled geometry (item counts, flag bytes,
//!    frame sizes) at decode, so corrupt or malicious frames surface as
//!    codec errors before any scan work — see [`wire`].
//! 4. **Liveness is part of the protocol.** `Heartbeat`/`HeartbeatAck`
//!    frames let the failure detector probe a node between requests; for
//!    live (streaming) nodes the ack doubles as the cluster-level seal
//!    poll, so a quiet remote stream still seals by age.

//! Alongside the internal binary protocol, this module carries the
//! public front door: a zero-dependency HTTP/1.1 codec ([`http`]) and
//! the JSON serving edge ([`edge`]) that maps HTTP requests onto the
//! Orchestrator's admission lanes — untrusted-input hostile to the same
//! standard as the wire codec.

pub mod edge;
pub mod http;
pub mod tcp;
pub mod wire;

pub use edge::{EdgeConfig, EdgeServer};
pub use http::{HttpError, Limits, Request, Response};
pub use tcp::{serve_node, serve_node_loop, RemoteNode};
pub use wire::{BatchReplyItem, Message};
