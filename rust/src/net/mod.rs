//! TCP transport — the cloud-deployment path.
//!
//! In the paper's deployment the Orchestrator and the ν SLSH nodes are
//! separate cloud VMs. This module provides that wire path: a
//! length-prefixed binary protocol ([`wire`]), a node server
//! ([`serve_node`]) run by `dslsh serve-node`, and a [`RemoteNode`] client
//! implementing [`NodeHandle`](crate::coordinator::NodeHandle) so the
//! Orchestrator drives remote processes exactly like in-process nodes.

pub mod tcp;
pub mod wire;

pub use tcp::{serve_node, RemoteNode};
pub use wire::{BatchReplyItem, Message};
