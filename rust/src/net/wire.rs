//! Wire protocol: length-prefixed frames carrying bytes-encoded messages.
//!
//! Frame = `u32` payload length (LE) + payload. Payload = `u8` tag +
//! fields via [`crate::util::bytes`]. The protocol is strictly
//! request/response per node connection; the Root broadcasts hash
//! *specifications* (seed + params), not function tables — nodes
//! reconstruct bit-identical instances locally.

use std::io::{Read, Write};

use crate::coordinator::admission::{BudgetPolicy, Class};
use crate::data::Dataset;
use crate::knn::heap::Neighbor;
use crate::slsh::SlshParams;
use crate::util::bytes::{self, CodecError};
use crate::util::json::Json;

/// Maximum frame payload (guards against hostile/corrupt peers).
pub const MAX_FRAME: u32 = 1 << 30;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Root → node: build tables over the shard.
    Build {
        node_id: u32,
        id_base: u64,
        p: u32,
        /// SLSH parameters (JSON — the broadcastable hash spec).
        params: SlshParams,
        shard: Dataset,
    },
    /// Node → root: construction finished.
    BuildDone { node_id: u32, shard_len: u64, build_ms: f64 },
    /// Root → node: resolve a query.
    Query { qid: u64, q: Vec<f32> },
    /// Node → root: node-local K-NN + per-core comparison counts.
    Reply { qid: u64, neighbors: Vec<Neighbor>, comparisons: Vec<u64>, inner_probes: u64 },
    /// Root → node: resolve a block of `nq` queries (`qs` row-major
    /// `nq × dim`; query `i` has id `qid0 + i`). One frame per batch
    /// amortizes the round trip the per-query protocol pays.
    QueryBatch { qid0: u64, nq: u64, qs: Vec<f32> },
    /// Root → node: a [`QueryBatch`](Message::QueryBatch) that carries
    /// the admission cut's remaining latency budget (µs until the batch's
    /// most urgent deadline, computed once at dispatch; `u64::MAX` = no
    /// budget), the node-side enforcement policy, and the cut's
    /// scheduling class (monitor if any monitor rides it). Remote nodes
    /// enforce the same cut the orchestrator-side cutter made: per-class
    /// overrun accounting under `LogOnly`, early-exit partial scans under
    /// `PartialResults`, and reject-before-scan under `Shed` when the
    /// budget is already spent on arrival.
    QueryBatchBudget {
        qid0: u64,
        nq: u64,
        budget_us: u64,
        class: Class,
        policy: BudgetPolicy,
        qs: Vec<f32>,
    },
    /// Node → root: per-query answers for one batch, in qid order.
    ReplyBatch { qid0: u64, replies: Vec<BatchReplyItem> },
    /// Root → node: drain and exit.
    Shutdown,
}

/// One query's answer inside a [`Message::ReplyBatch`]. The enforcement
/// flags travel as one validated byte: bit 0 = `partial` (the scan was
/// cut short by the budget), bit 1 = `shed` (the node rejected the batch
/// before any scan work; implies `partial`). Any other byte — including
/// the inconsistent `shed`-without-`partial` — is rejected as `BadTag`.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchReplyItem {
    pub neighbors: Vec<Neighbor>,
    pub comparisons: Vec<u64>,
    pub inner_probes: u64,
    pub partial: bool,
    pub shed: bool,
}

const TAG_BUILD: u8 = 1;
const TAG_BUILD_DONE: u8 = 2;
const TAG_QUERY: u8 = 3;
const TAG_REPLY: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_QUERY_BATCH: u8 = 6;
const TAG_REPLY_BATCH: u8 = 7;
const TAG_QUERY_BATCH_BUDGET: u8 = 8;

/// Sanity cap on per-message collection sizes (hostile/corrupt peers).
const MAX_ITEMS: usize = 1 << 20;

/// Shared hostile-input check for batch frames (`QueryBatch` and
/// `QueryBatchBudget`): the peer-controlled item count must be within the
/// sanity cap, and `nq × dim` must equal the shipped float count without
/// overflowing — a mismatched batch resolved as-if-rectangular would scan
/// byte-shifted garbage for every later query. Returns the validated
/// count as `usize`.
pub fn validate_batch_geometry(nq: u64, floats: usize, dim: usize) -> Result<usize, CodecError> {
    if nq > MAX_ITEMS as u64 {
        return Err(CodecError::TooLong(nq, MAX_ITEMS as u64));
    }
    let nq = nq as usize;
    if dim == 0 || nq.checked_mul(dim) != Some(floats) {
        return Err(CodecError::BadGeometry {
            items: nq as u64,
            len: floats as u64,
            dim: dim as u64,
        });
    }
    Ok(nq)
}

fn write_neighbors(out: &mut Vec<u8>, neighbors: &[Neighbor]) {
    bytes::write_u64(out, neighbors.len() as u64).unwrap();
    for n in neighbors {
        bytes::write_u64(out, n.id).unwrap();
        bytes::write_f32(out, n.dist).unwrap();
        bytes::write_u8(out, n.label as u8).unwrap();
    }
}

fn read_neighbors(r: &mut std::io::Cursor<&[u8]>) -> Result<Vec<Neighbor>, CodecError> {
    let n = bytes::read_u64(r)? as usize;
    if n > MAX_ITEMS {
        return Err(CodecError::TooLong(n as u64, MAX_ITEMS as u64));
    }
    let mut neighbors = Vec::with_capacity(n);
    for _ in 0..n {
        neighbors.push(Neighbor {
            id: bytes::read_u64(r)?,
            dist: bytes::read_f32(r)?,
            label: bytes::read_u8(r)? != 0,
        });
    }
    Ok(neighbors)
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Message::Build { node_id, id_base, p, params, shard } => {
                bytes::write_u8(&mut out, TAG_BUILD).unwrap();
                bytes::write_u32(&mut out, *node_id).unwrap();
                bytes::write_u64(&mut out, *id_base).unwrap();
                bytes::write_u32(&mut out, *p).unwrap();
                bytes::write_string(&mut out, &params.to_json().to_string_compact()).unwrap();
                shard.write_to(&mut out).unwrap();
            }
            Message::BuildDone { node_id, shard_len, build_ms } => {
                bytes::write_u8(&mut out, TAG_BUILD_DONE).unwrap();
                bytes::write_u32(&mut out, *node_id).unwrap();
                bytes::write_u64(&mut out, *shard_len).unwrap();
                bytes::write_f64(&mut out, *build_ms).unwrap();
            }
            Message::Query { qid, q } => {
                bytes::write_u8(&mut out, TAG_QUERY).unwrap();
                bytes::write_u64(&mut out, *qid).unwrap();
                bytes::write_f32_vec(&mut out, q).unwrap();
            }
            Message::Reply { qid, neighbors, comparisons, inner_probes } => {
                bytes::write_u8(&mut out, TAG_REPLY).unwrap();
                bytes::write_u64(&mut out, *qid).unwrap();
                write_neighbors(&mut out, neighbors);
                bytes::write_u64_vec(&mut out, comparisons).unwrap();
                bytes::write_u64(&mut out, *inner_probes).unwrap();
            }
            Message::QueryBatch { qid0, nq, qs } => {
                bytes::write_u8(&mut out, TAG_QUERY_BATCH).unwrap();
                bytes::write_u64(&mut out, *qid0).unwrap();
                bytes::write_u64(&mut out, *nq).unwrap();
                bytes::write_f32_vec(&mut out, qs).unwrap();
            }
            Message::QueryBatchBudget { qid0, nq, budget_us, class, policy, qs } => {
                bytes::write_u8(&mut out, TAG_QUERY_BATCH_BUDGET).unwrap();
                bytes::write_u64(&mut out, *qid0).unwrap();
                bytes::write_u64(&mut out, *nq).unwrap();
                bytes::write_u64(&mut out, *budget_us).unwrap();
                bytes::write_u8(&mut out, class.as_u8()).unwrap();
                bytes::write_u8(&mut out, policy.as_u8()).unwrap();
                bytes::write_f32_vec(&mut out, qs).unwrap();
            }
            Message::ReplyBatch { qid0, replies } => {
                bytes::write_u8(&mut out, TAG_REPLY_BATCH).unwrap();
                bytes::write_u64(&mut out, *qid0).unwrap();
                bytes::write_u64(&mut out, replies.len() as u64).unwrap();
                for item in replies {
                    write_neighbors(&mut out, &item.neighbors);
                    bytes::write_u64_vec(&mut out, &item.comparisons).unwrap();
                    bytes::write_u64(&mut out, item.inner_probes).unwrap();
                    let flags = item.partial as u8 | ((item.shed as u8) << 1);
                    bytes::write_u8(&mut out, flags).unwrap();
                }
            }
            Message::Shutdown => {
                bytes::write_u8(&mut out, TAG_SHUTDOWN).unwrap();
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Message, CodecError> {
        let mut r = std::io::Cursor::new(buf);
        let tag = bytes::read_u8(&mut r)?;
        match tag {
            TAG_BUILD => {
                let node_id = bytes::read_u32(&mut r)?;
                let id_base = bytes::read_u64(&mut r)?;
                let p = bytes::read_u32(&mut r)?;
                let params_json = bytes::read_string(&mut r)?;
                let params = Json::parse(&params_json)
                    .ok()
                    .as_ref()
                    .and_then(SlshParams::from_json)
                    .ok_or(CodecError::BadTag(0, "SlshParams"))?;
                let shard = Dataset::read_from(&mut r)?;
                Ok(Message::Build { node_id, id_base, p, params, shard })
            }
            TAG_BUILD_DONE => Ok(Message::BuildDone {
                node_id: bytes::read_u32(&mut r)?,
                shard_len: bytes::read_u64(&mut r)?,
                build_ms: bytes::read_f64(&mut r)?,
            }),
            TAG_QUERY => Ok(Message::Query {
                qid: bytes::read_u64(&mut r)?,
                q: bytes::read_f32_vec(&mut r)?,
            }),
            TAG_REPLY => {
                let qid = bytes::read_u64(&mut r)?;
                let neighbors = read_neighbors(&mut r)?;
                let comparisons = bytes::read_u64_vec(&mut r)?;
                let inner_probes = bytes::read_u64(&mut r)?;
                Ok(Message::Reply { qid, neighbors, comparisons, inner_probes })
            }
            TAG_QUERY_BATCH => Ok(Message::QueryBatch {
                qid0: bytes::read_u64(&mut r)?,
                nq: bytes::read_u64(&mut r)?,
                qs: bytes::read_f32_vec(&mut r)?,
            }),
            TAG_QUERY_BATCH_BUDGET => {
                let qid0 = bytes::read_u64(&mut r)?;
                let nq = bytes::read_u64(&mut r)?;
                let budget_us = bytes::read_u64(&mut r)?;
                // Peer-controlled class byte: reject unknown lanes rather
                // than defaulting (a corrupt byte must not silently move
                // traffic between scheduling classes).
                let class_b = bytes::read_u8(&mut r)?;
                let class = Class::from_u8(class_b)
                    .ok_or(CodecError::BadTag(class_b as u32, "Class"))?;
                // Peer-controlled policy byte: same rule — a corrupt byte
                // must not silently change enforcement behavior.
                let policy_b = bytes::read_u8(&mut r)?;
                let policy = BudgetPolicy::from_u8(policy_b)
                    .ok_or(CodecError::BadTag(policy_b as u32, "BudgetPolicy"))?;
                let qs = bytes::read_f32_vec(&mut r)?;
                Ok(Message::QueryBatchBudget { qid0, nq, budget_us, class, policy, qs })
            }
            TAG_REPLY_BATCH => {
                let qid0 = bytes::read_u64(&mut r)?;
                let count = bytes::read_u64(&mut r)? as usize;
                if count > MAX_ITEMS {
                    return Err(CodecError::TooLong(count as u64, MAX_ITEMS as u64));
                }
                let mut replies = Vec::with_capacity(count);
                for _ in 0..count {
                    let neighbors = read_neighbors(&mut r)?;
                    let comparisons = bytes::read_u64_vec(&mut r)?;
                    let inner_probes = bytes::read_u64(&mut r)?;
                    // Flags byte: only {none, partial, partial|shed} are
                    // coherent states; everything else (including shed
                    // without partial) is a hostile/corrupt peer.
                    let flags = bytes::read_u8(&mut r)?;
                    let (partial, shed) = match flags {
                        0 => (false, false),
                        1 => (true, false),
                        3 => (true, true),
                        f => return Err(CodecError::BadTag(f as u32, "ReplyFlags")),
                    };
                    replies.push(BatchReplyItem {
                        neighbors,
                        comparisons,
                        inner_probes,
                        partial,
                        shed,
                    });
                }
                Ok(Message::ReplyBatch { qid0, replies })
            }
            TAG_SHUTDOWN => Ok(Message::Shutdown),
            t => Err(CodecError::BadTag(t as u32, "Message")),
        }
    }

    /// Write as a length-prefixed frame.
    pub fn write_frame<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let payload = self.encode();
        w.write_all(&(payload.len() as u32).to_le_bytes())?;
        w.write_all(&payload)?;
        w.flush()
    }

    /// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
    pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Message>, CodecError> {
        let mut len_buf = [0u8; 4];
        match r.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e.into()),
        }
        let len = u32::from_le_bytes(len_buf);
        if len > MAX_FRAME {
            return Err(CodecError::TooLong(len as u64, MAX_FRAME as u64));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        Ok(Some(Message::decode(&payload)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_dataset() -> Dataset {
        let mut d = Dataset::new("wire-test", 3);
        d.push(&[1.0, 2.0, 3.0], false);
        d.push(&[4.0, 5.0, 6.0], true);
        d
    }

    fn roundtrip(m: &Message) -> Message {
        let mut buf = Vec::new();
        m.write_frame(&mut buf).unwrap();
        let got = Message::read_frame(&mut std::io::Cursor::new(buf)).unwrap().unwrap();
        got
    }

    #[test]
    fn build_roundtrip() {
        let m = Message::Build {
            node_id: 3,
            id_base: 1000,
            p: 8,
            params: SlshParams::paper_onset(30, 20.0, 180.0, 42),
            shard: sample_dataset(),
        };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn query_reply_roundtrip() {
        let q = Message::Query { qid: 9, q: vec![1.5, -2.0, 0.0] };
        assert_eq!(roundtrip(&q), q);
        let r = Message::Reply {
            qid: 9,
            neighbors: vec![
                Neighbor { id: 5, dist: 1.25, label: true },
                Neighbor { id: 11, dist: 3.5, label: false },
            ],
            comparisons: vec![10, 20, 30],
            inner_probes: 4,
        };
        assert_eq!(roundtrip(&r), r);
    }

    /// One of each enforcement-relevant frame shape, spanning lanes,
    /// policies, flags and the no-budget sentinel — the corpus the
    /// roundtrip and truncation property tests sweep.
    fn budget_frame_corpus() -> Vec<Message> {
        let mut frames = Vec::new();
        // Geometry sweep × class × policy for the budget frame.
        for (nq, dim) in [(1u64, 1usize), (2, 3), (4, 7), (3, 30)] {
            for class in [Class::Monitor, Class::Analytics] {
                for policy in
                    [BudgetPolicy::LogOnly, BudgetPolicy::PartialResults, BudgetPolicy::Shed]
                {
                    frames.push(Message::QueryBatchBudget {
                        qid0: 77,
                        nq,
                        budget_us: 1500,
                        class,
                        policy,
                        qs: (0..nq as usize * dim).map(|i| i as f32 * 0.5).collect(),
                    });
                }
            }
        }
        // The no-budget sentinel used by caller-formed blocks.
        frames.push(Message::QueryBatchBudget {
            qid0: 0,
            nq: 1,
            budget_us: u64::MAX,
            class: Class::Analytics,
            policy: BudgetPolicy::LogOnly,
            qs: vec![9.0, 8.0, 7.0],
        });
        // Reply batches across every coherent flag state, empty and
        // non-empty neighbor sets, empty batch included.
        frames.push(Message::ReplyBatch { qid0: 9, replies: vec![] });
        frames.push(Message::ReplyBatch {
            qid0: 40,
            replies: vec![
                BatchReplyItem {
                    neighbors: vec![Neighbor { id: 5, dist: 1.25, label: true }],
                    comparisons: vec![10, 20],
                    inner_probes: 1,
                    partial: false,
                    shed: false,
                },
                BatchReplyItem {
                    neighbors: vec![Neighbor { id: 6, dist: 2.5, label: false }],
                    comparisons: vec![4, 0],
                    inner_probes: 0,
                    partial: true,
                    shed: false,
                },
                BatchReplyItem {
                    neighbors: vec![],
                    comparisons: vec![0, 0],
                    inner_probes: 0,
                    partial: true,
                    shed: true,
                },
            ],
        });
        frames
    }

    #[test]
    fn batch_messages_roundtrip() {
        let q = Message::QueryBatch { qid0: 40, nq: 2, qs: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        assert_eq!(roundtrip(&q), q);
    }

    #[test]
    fn budget_and_reply_frames_roundtrip_across_sweep() {
        for m in budget_frame_corpus() {
            assert_eq!(roundtrip(&m), m, "frame {m:?}");
        }
    }

    #[test]
    fn budget_and_reply_frames_reject_truncation_at_every_byte() {
        // Property: EVERY strict prefix of a valid payload must decode to
        // an error — never panic, never silently succeed with less data.
        for m in budget_frame_corpus() {
            let payload = m.encode();
            assert_eq!(Message::decode(&payload).unwrap(), m);
            for cut in 0..payload.len() {
                assert!(
                    Message::decode(&payload[..cut]).is_err(),
                    "decode must fail at cut {cut}/{} for {m:?}",
                    payload.len()
                );
            }
            // Framed variant: valid length prefix, payload cut short.
            let mut framed = Vec::new();
            m.write_frame(&mut framed).unwrap();
            for cut in 4..framed.len() {
                assert!(
                    Message::read_frame(&mut std::io::Cursor::new(&framed[..cut])).is_err(),
                    "read_frame must fail at cut {cut} for {m:?}"
                );
            }
        }
    }

    #[test]
    fn bad_class_byte_is_rejected() {
        let m = Message::QueryBatchBudget {
            qid0: 1,
            nq: 1,
            budget_us: 100,
            class: Class::Monitor,
            policy: BudgetPolicy::LogOnly,
            qs: vec![1.0, 2.0],
        };
        let mut payload = m.encode();
        // Payload layout: tag(1) + qid0(8) + nq(8) + budget_us(8) +
        // class(1) + policy(1) + floats. Flip the class byte to an
        // unknown lane.
        assert_eq!(payload[25], Class::Monitor.as_u8());
        payload[25] = 7;
        assert!(matches!(Message::decode(&payload), Err(CodecError::BadTag(7, _))));
        // Round-tripping the class codec itself: both lanes survive,
        // unknown bytes do not.
        for class in [Class::Monitor, Class::Analytics] {
            assert_eq!(Class::from_u8(class.as_u8()), Some(class));
        }
        assert_eq!(Class::from_u8(2), None);
    }

    #[test]
    fn bad_policy_byte_is_rejected() {
        let m = Message::QueryBatchBudget {
            qid0: 1,
            nq: 1,
            budget_us: 100,
            class: Class::Monitor,
            policy: BudgetPolicy::Shed,
            qs: vec![1.0, 2.0],
        };
        let mut payload = m.encode();
        // Policy byte sits right after the class byte.
        assert_eq!(payload[26], BudgetPolicy::Shed.as_u8());
        for bad in [3u8, 7, 255] {
            payload[26] = bad;
            let got = Message::decode(&payload);
            assert!(
                matches!(got, Err(CodecError::BadTag(b, "BudgetPolicy")) if b == bad as u32),
                "policy byte {bad} must be rejected"
            );
        }
        // The policy codec itself: all three policies survive, unknown
        // bytes do not.
        for policy in [BudgetPolicy::LogOnly, BudgetPolicy::PartialResults, BudgetPolicy::Shed] {
            assert_eq!(BudgetPolicy::from_u8(policy.as_u8()), Some(policy));
        }
        assert_eq!(BudgetPolicy::from_u8(3), None);
    }

    #[test]
    fn bad_reply_flags_byte_is_rejected() {
        let m = Message::ReplyBatch {
            qid0: 4,
            replies: vec![BatchReplyItem {
                neighbors: vec![],
                comparisons: vec![1],
                inner_probes: 0,
                partial: false,
                shed: false,
            }],
        };
        let mut payload = m.encode();
        // The flags byte is the LAST payload byte (single item).
        let last = payload.len() - 1;
        // 2 = shed-without-partial (incoherent), >3 = unknown bits.
        for bad in [2u8, 4, 9, 255] {
            payload[last] = bad;
            let got = Message::decode(&payload);
            assert!(
                matches!(got, Err(CodecError::BadTag(b, "ReplyFlags")) if b == bad as u32),
                "flags byte {bad} must be rejected"
            );
        }
    }

    #[test]
    fn batch_geometry_validation() {
        // Accepts rectangular blocks (including the empty batch).
        assert_eq!(validate_batch_geometry(4, 12, 3).unwrap(), 4);
        assert_eq!(validate_batch_geometry(0, 0, 3).unwrap(), 0);
        // Mismatched float count: off by one either way.
        assert!(matches!(
            validate_batch_geometry(4, 11, 3),
            Err(CodecError::BadGeometry { items: 4, len: 11, dim: 3 })
        ));
        assert!(matches!(
            validate_batch_geometry(4, 13, 3),
            Err(CodecError::BadGeometry { .. })
        ));
        // Zero dimension can never form a valid batch.
        assert!(matches!(
            validate_batch_geometry(1, 0, 0),
            Err(CodecError::BadGeometry { .. })
        ));
        // Oversized count: rejected by the sanity cap before any multiply.
        assert!(matches!(
            validate_batch_geometry(MAX_ITEMS as u64 + 1, 30, 30),
            Err(CodecError::TooLong(..))
        ));
        // Hostile count that would overflow nq * dim on 64-bit is caught
        // by the cap; a count just inside the cap with a huge implied
        // payload still fails the equality check.
        assert!(matches!(
            validate_batch_geometry(u64::MAX, 30, 30),
            Err(CodecError::TooLong(..))
        ));
        assert!(matches!(
            validate_batch_geometry(MAX_ITEMS as u64, 30, usize::MAX),
            Err(CodecError::BadGeometry { .. }) | Err(CodecError::TooLong(..))
        ));
    }

    #[test]
    fn lifecycle_messages_roundtrip() {
        let d = Message::BuildDone { node_id: 1, shard_len: 500, build_ms: 12.5 };
        assert_eq!(roundtrip(&d), d);
        assert_eq!(roundtrip(&Message::Shutdown), Message::Shutdown);
    }

    #[test]
    fn clean_eof_is_none() {
        let empty: &[u8] = &[];
        assert!(Message::read_frame(&mut std::io::Cursor::new(empty)).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_is_error() {
        let mut buf = Vec::new();
        Message::Shutdown.write_frame(&mut buf).unwrap();
        buf.truncate(buf.len() - 1); // valid length prefix, short payload
        let mut long = Vec::new();
        Message::Query { qid: 1, q: vec![1.0; 64] }.write_frame(&mut long).unwrap();
        long.truncate(20);
        assert!(Message::read_frame(&mut std::io::Cursor::new(long)).is_err());
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(matches!(Message::decode(&[99]), Err(CodecError::BadTag(99, _))));
    }
}
