//! Wire protocol: length-prefixed frames carrying bytes-encoded messages.
//!
//! Frame = `u32` payload length (LE) + payload. Payload = `u8` tag +
//! fields via [`crate::util::bytes`]. The protocol is strictly
//! request/response per node connection; the Root broadcasts hash
//! *specifications* (seed + params), not function tables — nodes
//! reconstruct bit-identical instances locally.

use std::io::{Read, Write};

use crate::data::Dataset;
use crate::knn::heap::Neighbor;
use crate::slsh::SlshParams;
use crate::util::bytes::{self, CodecError};
use crate::util::json::Json;

/// Maximum frame payload (guards against hostile/corrupt peers).
pub const MAX_FRAME: u32 = 1 << 30;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Root → node: build tables over the shard.
    Build {
        node_id: u32,
        id_base: u64,
        p: u32,
        /// SLSH parameters (JSON — the broadcastable hash spec).
        params: SlshParams,
        shard: Dataset,
    },
    /// Node → root: construction finished.
    BuildDone { node_id: u32, shard_len: u64, build_ms: f64 },
    /// Root → node: resolve a query.
    Query { qid: u64, q: Vec<f32> },
    /// Node → root: node-local K-NN + per-core comparison counts.
    Reply { qid: u64, neighbors: Vec<Neighbor>, comparisons: Vec<u64>, inner_probes: u64 },
    /// Root → node: resolve a block of `nq` queries (`qs` row-major
    /// `nq × dim`; query `i` has id `qid0 + i`). One frame per batch
    /// amortizes the round trip the per-query protocol pays.
    QueryBatch { qid0: u64, nq: u64, qs: Vec<f32> },
    /// Node → root: per-query answers for one batch, in qid order.
    ReplyBatch { qid0: u64, replies: Vec<BatchReplyItem> },
    /// Root → node: drain and exit.
    Shutdown,
}

/// One query's answer inside a [`Message::ReplyBatch`].
#[derive(Debug, Clone, PartialEq)]
pub struct BatchReplyItem {
    pub neighbors: Vec<Neighbor>,
    pub comparisons: Vec<u64>,
    pub inner_probes: u64,
}

const TAG_BUILD: u8 = 1;
const TAG_BUILD_DONE: u8 = 2;
const TAG_QUERY: u8 = 3;
const TAG_REPLY: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_QUERY_BATCH: u8 = 6;
const TAG_REPLY_BATCH: u8 = 7;

/// Sanity cap on per-message collection sizes (hostile/corrupt peers).
const MAX_ITEMS: usize = 1 << 20;

fn write_neighbors(out: &mut Vec<u8>, neighbors: &[Neighbor]) {
    bytes::write_u64(out, neighbors.len() as u64).unwrap();
    for n in neighbors {
        bytes::write_u64(out, n.id).unwrap();
        bytes::write_f32(out, n.dist).unwrap();
        bytes::write_u8(out, n.label as u8).unwrap();
    }
}

fn read_neighbors(r: &mut std::io::Cursor<&[u8]>) -> Result<Vec<Neighbor>, CodecError> {
    let n = bytes::read_u64(r)? as usize;
    if n > MAX_ITEMS {
        return Err(CodecError::TooLong(n as u64, MAX_ITEMS as u64));
    }
    let mut neighbors = Vec::with_capacity(n);
    for _ in 0..n {
        neighbors.push(Neighbor {
            id: bytes::read_u64(r)?,
            dist: bytes::read_f32(r)?,
            label: bytes::read_u8(r)? != 0,
        });
    }
    Ok(neighbors)
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Message::Build { node_id, id_base, p, params, shard } => {
                bytes::write_u8(&mut out, TAG_BUILD).unwrap();
                bytes::write_u32(&mut out, *node_id).unwrap();
                bytes::write_u64(&mut out, *id_base).unwrap();
                bytes::write_u32(&mut out, *p).unwrap();
                bytes::write_string(&mut out, &params.to_json().to_string_compact()).unwrap();
                shard.write_to(&mut out).unwrap();
            }
            Message::BuildDone { node_id, shard_len, build_ms } => {
                bytes::write_u8(&mut out, TAG_BUILD_DONE).unwrap();
                bytes::write_u32(&mut out, *node_id).unwrap();
                bytes::write_u64(&mut out, *shard_len).unwrap();
                bytes::write_f64(&mut out, *build_ms).unwrap();
            }
            Message::Query { qid, q } => {
                bytes::write_u8(&mut out, TAG_QUERY).unwrap();
                bytes::write_u64(&mut out, *qid).unwrap();
                bytes::write_f32_vec(&mut out, q).unwrap();
            }
            Message::Reply { qid, neighbors, comparisons, inner_probes } => {
                bytes::write_u8(&mut out, TAG_REPLY).unwrap();
                bytes::write_u64(&mut out, *qid).unwrap();
                write_neighbors(&mut out, neighbors);
                bytes::write_u64_vec(&mut out, comparisons).unwrap();
                bytes::write_u64(&mut out, *inner_probes).unwrap();
            }
            Message::QueryBatch { qid0, nq, qs } => {
                bytes::write_u8(&mut out, TAG_QUERY_BATCH).unwrap();
                bytes::write_u64(&mut out, *qid0).unwrap();
                bytes::write_u64(&mut out, *nq).unwrap();
                bytes::write_f32_vec(&mut out, qs).unwrap();
            }
            Message::ReplyBatch { qid0, replies } => {
                bytes::write_u8(&mut out, TAG_REPLY_BATCH).unwrap();
                bytes::write_u64(&mut out, *qid0).unwrap();
                bytes::write_u64(&mut out, replies.len() as u64).unwrap();
                for item in replies {
                    write_neighbors(&mut out, &item.neighbors);
                    bytes::write_u64_vec(&mut out, &item.comparisons).unwrap();
                    bytes::write_u64(&mut out, item.inner_probes).unwrap();
                }
            }
            Message::Shutdown => {
                bytes::write_u8(&mut out, TAG_SHUTDOWN).unwrap();
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Message, CodecError> {
        let mut r = std::io::Cursor::new(buf);
        let tag = bytes::read_u8(&mut r)?;
        match tag {
            TAG_BUILD => {
                let node_id = bytes::read_u32(&mut r)?;
                let id_base = bytes::read_u64(&mut r)?;
                let p = bytes::read_u32(&mut r)?;
                let params_json = bytes::read_string(&mut r)?;
                let params = Json::parse(&params_json)
                    .ok()
                    .as_ref()
                    .and_then(SlshParams::from_json)
                    .ok_or(CodecError::BadTag(0, "SlshParams"))?;
                let shard = Dataset::read_from(&mut r)?;
                Ok(Message::Build { node_id, id_base, p, params, shard })
            }
            TAG_BUILD_DONE => Ok(Message::BuildDone {
                node_id: bytes::read_u32(&mut r)?,
                shard_len: bytes::read_u64(&mut r)?,
                build_ms: bytes::read_f64(&mut r)?,
            }),
            TAG_QUERY => Ok(Message::Query {
                qid: bytes::read_u64(&mut r)?,
                q: bytes::read_f32_vec(&mut r)?,
            }),
            TAG_REPLY => {
                let qid = bytes::read_u64(&mut r)?;
                let neighbors = read_neighbors(&mut r)?;
                let comparisons = bytes::read_u64_vec(&mut r)?;
                let inner_probes = bytes::read_u64(&mut r)?;
                Ok(Message::Reply { qid, neighbors, comparisons, inner_probes })
            }
            TAG_QUERY_BATCH => Ok(Message::QueryBatch {
                qid0: bytes::read_u64(&mut r)?,
                nq: bytes::read_u64(&mut r)?,
                qs: bytes::read_f32_vec(&mut r)?,
            }),
            TAG_REPLY_BATCH => {
                let qid0 = bytes::read_u64(&mut r)?;
                let count = bytes::read_u64(&mut r)? as usize;
                if count > MAX_ITEMS {
                    return Err(CodecError::TooLong(count as u64, MAX_ITEMS as u64));
                }
                let mut replies = Vec::with_capacity(count);
                for _ in 0..count {
                    replies.push(BatchReplyItem {
                        neighbors: read_neighbors(&mut r)?,
                        comparisons: bytes::read_u64_vec(&mut r)?,
                        inner_probes: bytes::read_u64(&mut r)?,
                    });
                }
                Ok(Message::ReplyBatch { qid0, replies })
            }
            TAG_SHUTDOWN => Ok(Message::Shutdown),
            t => Err(CodecError::BadTag(t as u32, "Message")),
        }
    }

    /// Write as a length-prefixed frame.
    pub fn write_frame<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let payload = self.encode();
        w.write_all(&(payload.len() as u32).to_le_bytes())?;
        w.write_all(&payload)?;
        w.flush()
    }

    /// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
    pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Message>, CodecError> {
        let mut len_buf = [0u8; 4];
        match r.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e.into()),
        }
        let len = u32::from_le_bytes(len_buf);
        if len > MAX_FRAME {
            return Err(CodecError::TooLong(len as u64, MAX_FRAME as u64));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        Ok(Some(Message::decode(&payload)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::family::LayerSpec;

    fn sample_dataset() -> Dataset {
        let mut d = Dataset::new("wire-test", 3);
        d.push(&[1.0, 2.0, 3.0], false);
        d.push(&[4.0, 5.0, 6.0], true);
        d
    }

    fn roundtrip(m: &Message) -> Message {
        let mut buf = Vec::new();
        m.write_frame(&mut buf).unwrap();
        let got = Message::read_frame(&mut std::io::Cursor::new(buf)).unwrap().unwrap();
        got
    }

    #[test]
    fn build_roundtrip() {
        let m = Message::Build {
            node_id: 3,
            id_base: 1000,
            p: 8,
            params: SlshParams::paper_onset(30, 20.0, 180.0, 42),
            shard: sample_dataset(),
        };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn query_reply_roundtrip() {
        let q = Message::Query { qid: 9, q: vec![1.5, -2.0, 0.0] };
        assert_eq!(roundtrip(&q), q);
        let r = Message::Reply {
            qid: 9,
            neighbors: vec![
                Neighbor { id: 5, dist: 1.25, label: true },
                Neighbor { id: 11, dist: 3.5, label: false },
            ],
            comparisons: vec![10, 20, 30],
            inner_probes: 4,
        };
        assert_eq!(roundtrip(&r), r);
    }

    #[test]
    fn batch_messages_roundtrip() {
        let q = Message::QueryBatch { qid0: 40, nq: 2, qs: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        assert_eq!(roundtrip(&q), q);
        let r = Message::ReplyBatch {
            qid0: 40,
            replies: vec![
                BatchReplyItem {
                    neighbors: vec![Neighbor { id: 5, dist: 1.25, label: true }],
                    comparisons: vec![10, 20],
                    inner_probes: 1,
                },
                BatchReplyItem { neighbors: vec![], comparisons: vec![0, 0], inner_probes: 0 },
            ],
        };
        assert_eq!(roundtrip(&r), r);
    }

    #[test]
    fn lifecycle_messages_roundtrip() {
        let d = Message::BuildDone { node_id: 1, shard_len: 500, build_ms: 12.5 };
        assert_eq!(roundtrip(&d), d);
        assert_eq!(roundtrip(&Message::Shutdown), Message::Shutdown);
    }

    #[test]
    fn clean_eof_is_none() {
        let empty: &[u8] = &[];
        assert!(Message::read_frame(&mut std::io::Cursor::new(empty)).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_is_error() {
        let mut buf = Vec::new();
        Message::Shutdown.write_frame(&mut buf).unwrap();
        buf.truncate(buf.len() - 1); // valid length prefix, short payload
        let mut long = Vec::new();
        Message::Query { qid: 1, q: vec![1.0; 64] }.write_frame(&mut long).unwrap();
        long.truncate(20);
        assert!(Message::read_frame(&mut std::io::Cursor::new(long)).is_err());
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(matches!(Message::decode(&[99]), Err(CodecError::BadTag(99, _))));
    }
}
