//! Wire protocol: length-prefixed frames carrying bytes-encoded messages.
//!
//! Frame = `u32` payload length (LE) + payload. Payload = `u8` tag +
//! fields via [`crate::util::bytes`]. The protocol is strictly
//! request/response per node connection; the Root broadcasts hash
//! *specifications* (seed + params), not function tables — nodes
//! reconstruct bit-identical instances locally.

use std::io::{Read, Write};

use crate::coordinator::admission::{BudgetPolicy, Class};
use crate::data::Dataset;
use crate::knn::heap::Neighbor;
use crate::lsh::probe::MAX_PROBES;
use crate::slsh::SlshParams;
use crate::util::bytes::{self, CodecError};
use crate::util::json::Json;

/// Maximum frame payload (guards against hostile/corrupt peers).
pub const MAX_FRAME: u32 = 1 << 30;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Root → node: build tables over the shard.
    Build {
        node_id: u32,
        id_base: u64,
        p: u32,
        /// SLSH parameters (JSON — the broadcastable hash spec).
        params: SlshParams,
        shard: Dataset,
    },
    /// Node → root: construction finished.
    BuildDone { node_id: u32, shard_len: u64, build_ms: f64 },
    /// Root → node: resolve a query.
    Query { qid: u64, q: Vec<f32> },
    /// Node → root: node-local K-NN + per-core comparison counts.
    Reply { qid: u64, neighbors: Vec<Neighbor>, comparisons: Vec<u64>, inner_probes: u64 },
    /// Root → node: resolve a block of `nq` queries (`qs` row-major
    /// `nq × dim`; query `i` has id `qid0 + i`). One frame per batch
    /// amortizes the round trip the per-query protocol pays.
    QueryBatch { qid0: u64, nq: u64, qs: Vec<f32> },
    /// Root → node: a [`QueryBatch`](Message::QueryBatch) that carries
    /// the admission cut's remaining latency budget (µs until the batch's
    /// most urgent deadline, computed once at dispatch; `u64::MAX` = no
    /// budget), the node-side enforcement policy, and the cut's
    /// scheduling class (monitor if any monitor rides it). Remote nodes
    /// enforce the same cut the orchestrator-side cutter made: per-class
    /// overrun accounting under `LogOnly`, early-exit partial scans under
    /// `PartialResults`, and reject-before-scan under `Shed` when the
    /// budget is already spent on arrival. The frame also carries the
    /// cut's probe knobs: `probes` buckets visited per outer table
    /// (validated into `1..=MAX_PROBES` at decode — a zero or oversized
    /// count is a hostile/corrupt peer) and the per-query candidate cap
    /// `max_comparisons` (0 = unlimited). `budget_us = u64::MAX` keeps
    /// meaning "no deadline", so a spec-carrying request without a budget
    /// still rides this frame with its probe knobs intact. `trace` is the
    /// orchestrator-minted trace id (0 = untraced); it travels as a
    /// validated flag byte + id so spans survive the TCP hop — an
    /// incoherent pair (flag set with id 0, flag clear with a nonzero id,
    /// or unknown flag bits) is a hostile/corrupt peer, rejected as
    /// `BadTag`.
    QueryBatchBudget {
        qid0: u64,
        nq: u64,
        budget_us: u64,
        class: Class,
        policy: BudgetPolicy,
        probes: u32,
        max_comparisons: u64,
        trace: u64,
        qs: Vec<f32>,
    },
    /// Node → root: per-query answers for one batch, in qid order.
    /// Echoes the request's trace id (0 = untraced) with the same
    /// validated flag-byte + id encoding as the request frame, so the
    /// client can pin replies to the trace that asked for them.
    ReplyBatch { qid0: u64, trace: u64, replies: Vec<BatchReplyItem> },
    /// Root → node: spawn an EMPTY live (streaming) node instead of
    /// building over a shipped shard. `seal_points`/`seal_age_ns` are the
    /// node's [`SealPolicy`](crate::slsh::SealPolicy) (`u64::MAX` age =
    /// size-only); global ids are `id_base + insertion index`.
    BuildLive {
        node_id: u32,
        id_base: u64,
        p: u32,
        params: SlshParams,
        seal_points: u64,
        seal_age_ns: u64,
    },
    /// Root → node: append `n` labeled points to a live node's store
    /// (`points` row-major `n × dim`). Label count must equal `n` — a
    /// mismatch is rejected at decode as hostile geometry; the `n × dim`
    /// check happens server-side via [`validate_batch_geometry`], which
    /// knows the node's dim.
    InsertBatch { seq: u64, n: u64, points: Vec<f32>, labels: Vec<bool> },
    /// Node → root: ingest acknowledged. Carries one validated flags byte
    /// (bit 0 = "this call sealed at least one segment"); the byte must
    /// be coherent with `sealed_now` — anything else is a hostile/corrupt
    /// peer, rejected as `BadTag` like the reply-batch flags.
    InsertAck { seq: u64, accepted: u64, total: u64, sealed_now: u64, sealed_total: u64 },
    /// Root → node: failure-detector probe. A node that answers within
    /// the deadline is alive; the ack doubles as the cluster-level seal
    /// poll (see [`NodeHandle::heartbeat`]) so liveness checking and
    /// age-based seal sweeps ride one frame.
    ///
    /// [`NodeHandle::heartbeat`]: crate::coordinator::orchestrator::NodeHandle::heartbeat
    Heartbeat { seq: u64 },
    /// Node → root: heartbeat answer. `live` mirrors the node's ingest
    /// mode; a batch (non-live) node reports all counters zero. Carries
    /// one validated flags byte (bit 0 = `live`); a non-live ack with
    /// nonzero counters is incoherent — a hostile/corrupt peer, rejected
    /// as `BadTag` like the other flag bytes.
    HeartbeatAck { seq: u64, live: bool, total: u64, sealed_now: u64, sealed_total: u64 },
    /// Root → node: drain and exit.
    Shutdown,
}

/// One query's answer inside a [`Message::ReplyBatch`]. The enforcement
/// flags travel as one validated byte: bit 0 = `partial` (the scan was
/// cut short by the budget), bit 1 = `shed` (the node rejected the batch
/// before any scan work; implies `partial`). Any other byte — including
/// the inconsistent `shed`-without-`partial` — is rejected as `BadTag`.
/// `scan_ns`/`tables` are the node's per-query scan span (wall time on
/// the node's clock and outer tables consulted), flowing back so the
/// tracer can attribute where a slow query spent its time.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchReplyItem {
    pub neighbors: Vec<Neighbor>,
    pub comparisons: Vec<u64>,
    pub inner_probes: u64,
    pub scan_ns: u64,
    pub tables: u32,
    pub partial: bool,
    pub shed: bool,
}

/// Encode a trace id as the validated flag byte + id pair.
fn write_trace(out: &mut Vec<u8>, trace: u64) {
    bytes::write_u8(out, (trace != 0) as u8).unwrap();
    bytes::write_u64(out, trace).unwrap();
}

/// Decode and validate a trace flag byte + id pair: the flag must be 0/1
/// and must mirror `id != 0` — anything else is a hostile/corrupt peer.
fn read_trace(r: &mut std::io::Cursor<&[u8]>) -> Result<u64, CodecError> {
    let flags = bytes::read_u8(r)?;
    let trace = bytes::read_u64(r)?;
    if flags > 1 || (flags == 1) != (trace != 0) {
        return Err(CodecError::BadTag(flags as u32, "TraceFlags"));
    }
    Ok(trace)
}

const TAG_BUILD: u8 = 1;
const TAG_BUILD_DONE: u8 = 2;
const TAG_QUERY: u8 = 3;
const TAG_REPLY: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_QUERY_BATCH: u8 = 6;
const TAG_REPLY_BATCH: u8 = 7;
const TAG_QUERY_BATCH_BUDGET: u8 = 8;
const TAG_BUILD_LIVE: u8 = 9;
const TAG_INSERT_BATCH: u8 = 10;
const TAG_INSERT_ACK: u8 = 11;
const TAG_HEARTBEAT: u8 = 12;
const TAG_HEARTBEAT_ACK: u8 = 13;

/// Sanity cap on per-message collection sizes (hostile/corrupt peers).
const MAX_ITEMS: usize = 1 << 20;

/// Largest seal capacity a `BuildLive` frame may request — the server
/// pre-allocates extent + delta-table memory proportional to it, so a
/// hostile peer must not get to pick the size. [`RemoteNode::connect_live`]
/// rejects larger policies client-side with a clear error instead of a
/// server disconnect.
///
/// [`RemoteNode::connect_live`]: crate::net::tcp::RemoteNode::connect_live
pub const MAX_SEAL_POINTS: u64 = MAX_ITEMS as u64;

/// Shared hostile-input check for batch frames (`QueryBatch` and
/// `QueryBatchBudget`): the peer-controlled item count must be within the
/// sanity cap, and `nq × dim` must equal the shipped float count without
/// overflowing — a mismatched batch resolved as-if-rectangular would scan
/// byte-shifted garbage for every later query. Returns the validated
/// count as `usize`.
pub fn validate_batch_geometry(nq: u64, floats: usize, dim: usize) -> Result<usize, CodecError> {
    if nq > MAX_ITEMS as u64 {
        return Err(CodecError::TooLong(nq, MAX_ITEMS as u64));
    }
    let nq = nq as usize;
    if dim == 0 || nq.checked_mul(dim) != Some(floats) {
        return Err(CodecError::BadGeometry {
            items: nq as u64,
            len: floats as u64,
            dim: dim as u64,
        });
    }
    Ok(nq)
}

fn write_neighbors(out: &mut Vec<u8>, neighbors: &[Neighbor]) {
    bytes::write_u64(out, neighbors.len() as u64).unwrap();
    for n in neighbors {
        bytes::write_u64(out, n.id).unwrap();
        bytes::write_f32(out, n.dist).unwrap();
        bytes::write_u8(out, n.label as u8).unwrap();
    }
}

fn read_neighbors(r: &mut std::io::Cursor<&[u8]>) -> Result<Vec<Neighbor>, CodecError> {
    let n = bytes::read_u64(r)? as usize;
    if n > MAX_ITEMS {
        return Err(CodecError::TooLong(n as u64, MAX_ITEMS as u64));
    }
    let mut neighbors = Vec::with_capacity(n);
    for _ in 0..n {
        neighbors.push(Neighbor {
            id: bytes::read_u64(r)?,
            dist: bytes::read_f32(r)?,
            label: bytes::read_u8(r)? != 0,
        });
    }
    Ok(neighbors)
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Message::Build { node_id, id_base, p, params, shard } => {
                bytes::write_u8(&mut out, TAG_BUILD).unwrap();
                bytes::write_u32(&mut out, *node_id).unwrap();
                bytes::write_u64(&mut out, *id_base).unwrap();
                bytes::write_u32(&mut out, *p).unwrap();
                bytes::write_string(&mut out, &params.to_json().to_string_compact()).unwrap();
                shard.write_to(&mut out).unwrap();
            }
            Message::BuildDone { node_id, shard_len, build_ms } => {
                bytes::write_u8(&mut out, TAG_BUILD_DONE).unwrap();
                bytes::write_u32(&mut out, *node_id).unwrap();
                bytes::write_u64(&mut out, *shard_len).unwrap();
                bytes::write_f64(&mut out, *build_ms).unwrap();
            }
            Message::Query { qid, q } => {
                bytes::write_u8(&mut out, TAG_QUERY).unwrap();
                bytes::write_u64(&mut out, *qid).unwrap();
                bytes::write_f32_vec(&mut out, q).unwrap();
            }
            Message::Reply { qid, neighbors, comparisons, inner_probes } => {
                bytes::write_u8(&mut out, TAG_REPLY).unwrap();
                bytes::write_u64(&mut out, *qid).unwrap();
                write_neighbors(&mut out, neighbors);
                bytes::write_u64_vec(&mut out, comparisons).unwrap();
                bytes::write_u64(&mut out, *inner_probes).unwrap();
            }
            Message::QueryBatch { qid0, nq, qs } => {
                bytes::write_u8(&mut out, TAG_QUERY_BATCH).unwrap();
                bytes::write_u64(&mut out, *qid0).unwrap();
                bytes::write_u64(&mut out, *nq).unwrap();
                bytes::write_f32_vec(&mut out, qs).unwrap();
            }
            Message::QueryBatchBudget {
                qid0,
                nq,
                budget_us,
                class,
                policy,
                probes,
                max_comparisons,
                trace,
                qs,
            } => {
                bytes::write_u8(&mut out, TAG_QUERY_BATCH_BUDGET).unwrap();
                bytes::write_u64(&mut out, *qid0).unwrap();
                bytes::write_u64(&mut out, *nq).unwrap();
                bytes::write_u64(&mut out, *budget_us).unwrap();
                bytes::write_u8(&mut out, class.as_u8()).unwrap();
                bytes::write_u8(&mut out, policy.as_u8()).unwrap();
                bytes::write_u32(&mut out, *probes).unwrap();
                bytes::write_u64(&mut out, *max_comparisons).unwrap();
                write_trace(&mut out, *trace);
                bytes::write_f32_vec(&mut out, qs).unwrap();
            }
            Message::ReplyBatch { qid0, trace, replies } => {
                bytes::write_u8(&mut out, TAG_REPLY_BATCH).unwrap();
                bytes::write_u64(&mut out, *qid0).unwrap();
                write_trace(&mut out, *trace);
                bytes::write_u64(&mut out, replies.len() as u64).unwrap();
                for item in replies {
                    write_neighbors(&mut out, &item.neighbors);
                    bytes::write_u64_vec(&mut out, &item.comparisons).unwrap();
                    bytes::write_u64(&mut out, item.inner_probes).unwrap();
                    bytes::write_u64(&mut out, item.scan_ns).unwrap();
                    bytes::write_u32(&mut out, item.tables).unwrap();
                    let flags = item.partial as u8 | ((item.shed as u8) << 1);
                    bytes::write_u8(&mut out, flags).unwrap();
                }
            }
            Message::BuildLive { node_id, id_base, p, params, seal_points, seal_age_ns } => {
                bytes::write_u8(&mut out, TAG_BUILD_LIVE).unwrap();
                bytes::write_u32(&mut out, *node_id).unwrap();
                bytes::write_u64(&mut out, *id_base).unwrap();
                bytes::write_u32(&mut out, *p).unwrap();
                bytes::write_string(&mut out, &params.to_json().to_string_compact()).unwrap();
                bytes::write_u64(&mut out, *seal_points).unwrap();
                bytes::write_u64(&mut out, *seal_age_ns).unwrap();
            }
            Message::InsertBatch { seq, n, points, labels } => {
                bytes::write_u8(&mut out, TAG_INSERT_BATCH).unwrap();
                bytes::write_u64(&mut out, *seq).unwrap();
                bytes::write_u64(&mut out, *n).unwrap();
                bytes::write_f32_vec(&mut out, points).unwrap();
                bytes::write_bitvec(&mut out, labels).unwrap();
            }
            Message::InsertAck { seq, accepted, total, sealed_now, sealed_total } => {
                bytes::write_u8(&mut out, TAG_INSERT_ACK).unwrap();
                bytes::write_u64(&mut out, *seq).unwrap();
                bytes::write_u64(&mut out, *accepted).unwrap();
                bytes::write_u64(&mut out, *total).unwrap();
                bytes::write_u64(&mut out, *sealed_now).unwrap();
                bytes::write_u64(&mut out, *sealed_total).unwrap();
                bytes::write_u8(&mut out, (*sealed_now > 0) as u8).unwrap();
            }
            Message::Heartbeat { seq } => {
                bytes::write_u8(&mut out, TAG_HEARTBEAT).unwrap();
                bytes::write_u64(&mut out, *seq).unwrap();
            }
            Message::HeartbeatAck { seq, live, total, sealed_now, sealed_total } => {
                bytes::write_u8(&mut out, TAG_HEARTBEAT_ACK).unwrap();
                bytes::write_u64(&mut out, *seq).unwrap();
                bytes::write_u64(&mut out, *total).unwrap();
                bytes::write_u64(&mut out, *sealed_now).unwrap();
                bytes::write_u64(&mut out, *sealed_total).unwrap();
                bytes::write_u8(&mut out, *live as u8).unwrap();
            }
            Message::Shutdown => {
                bytes::write_u8(&mut out, TAG_SHUTDOWN).unwrap();
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Message, CodecError> {
        let mut r = std::io::Cursor::new(buf);
        let tag = bytes::read_u8(&mut r)?;
        match tag {
            TAG_BUILD => {
                let node_id = bytes::read_u32(&mut r)?;
                let id_base = bytes::read_u64(&mut r)?;
                let p = bytes::read_u32(&mut r)?;
                let params_json = bytes::read_string(&mut r)?;
                let params = Json::parse(&params_json)
                    .ok()
                    .as_ref()
                    .and_then(SlshParams::from_json)
                    .ok_or(CodecError::BadTag(0, "SlshParams"))?;
                let shard = Dataset::read_from(&mut r)?;
                Ok(Message::Build { node_id, id_base, p, params, shard })
            }
            TAG_BUILD_DONE => Ok(Message::BuildDone {
                node_id: bytes::read_u32(&mut r)?,
                shard_len: bytes::read_u64(&mut r)?,
                build_ms: bytes::read_f64(&mut r)?,
            }),
            TAG_QUERY => Ok(Message::Query {
                qid: bytes::read_u64(&mut r)?,
                q: bytes::read_f32_vec(&mut r)?,
            }),
            TAG_REPLY => {
                let qid = bytes::read_u64(&mut r)?;
                let neighbors = read_neighbors(&mut r)?;
                let comparisons = bytes::read_u64_vec(&mut r)?;
                let inner_probes = bytes::read_u64(&mut r)?;
                Ok(Message::Reply { qid, neighbors, comparisons, inner_probes })
            }
            TAG_QUERY_BATCH => Ok(Message::QueryBatch {
                qid0: bytes::read_u64(&mut r)?,
                nq: bytes::read_u64(&mut r)?,
                qs: bytes::read_f32_vec(&mut r)?,
            }),
            TAG_QUERY_BATCH_BUDGET => {
                let qid0 = bytes::read_u64(&mut r)?;
                let nq = bytes::read_u64(&mut r)?;
                let budget_us = bytes::read_u64(&mut r)?;
                // Peer-controlled class byte: reject unknown lanes rather
                // than defaulting (a corrupt byte must not silently move
                // traffic between scheduling classes).
                let class_b = bytes::read_u8(&mut r)?;
                let class = Class::from_u8(class_b)
                    .ok_or(CodecError::BadTag(class_b as u32, "Class"))?;
                // Peer-controlled policy byte: same rule — a corrupt byte
                // must not silently change enforcement behavior.
                let policy_b = bytes::read_u8(&mut r)?;
                let policy = BudgetPolicy::from_u8(policy_b)
                    .ok_or(CodecError::BadTag(policy_b as u32, "BudgetPolicy"))?;
                // Peer-controlled probe count: zero (no scan at all) and
                // counts past the enumeration cap are both hostile or
                // corrupt, never a real request.
                let probes = bytes::read_u32(&mut r)?;
                if probes == 0 || probes > MAX_PROBES {
                    return Err(CodecError::BadTag(probes, "Probes"));
                }
                let max_comparisons = bytes::read_u64(&mut r)?;
                let trace = read_trace(&mut r)?;
                let qs = bytes::read_f32_vec(&mut r)?;
                Ok(Message::QueryBatchBudget {
                    qid0,
                    nq,
                    budget_us,
                    class,
                    policy,
                    probes,
                    max_comparisons,
                    trace,
                    qs,
                })
            }
            TAG_REPLY_BATCH => {
                let qid0 = bytes::read_u64(&mut r)?;
                let trace = read_trace(&mut r)?;
                let count = bytes::read_u64(&mut r)? as usize;
                if count > MAX_ITEMS {
                    return Err(CodecError::TooLong(count as u64, MAX_ITEMS as u64));
                }
                let mut replies = Vec::with_capacity(count);
                for _ in 0..count {
                    let neighbors = read_neighbors(&mut r)?;
                    let comparisons = bytes::read_u64_vec(&mut r)?;
                    let inner_probes = bytes::read_u64(&mut r)?;
                    let scan_ns = bytes::read_u64(&mut r)?;
                    let tables = bytes::read_u32(&mut r)?;
                    // Flags byte: only {none, partial, partial|shed} are
                    // coherent states; everything else (including shed
                    // without partial) is a hostile/corrupt peer.
                    let flags = bytes::read_u8(&mut r)?;
                    let (partial, shed) = match flags {
                        0 => (false, false),
                        1 => (true, false),
                        3 => (true, true),
                        f => return Err(CodecError::BadTag(f as u32, "ReplyFlags")),
                    };
                    replies.push(BatchReplyItem {
                        neighbors,
                        comparisons,
                        inner_probes,
                        scan_ns,
                        tables,
                        partial,
                        shed,
                    });
                }
                Ok(Message::ReplyBatch { qid0, trace, replies })
            }
            TAG_BUILD_LIVE => {
                let node_id = bytes::read_u32(&mut r)?;
                let id_base = bytes::read_u64(&mut r)?;
                let p = bytes::read_u32(&mut r)?;
                let params_json = bytes::read_string(&mut r)?;
                let params = Json::parse(&params_json)
                    .ok()
                    .as_ref()
                    .and_then(SlshParams::from_json)
                    .ok_or(CodecError::BadTag(0, "SlshParams"))?;
                let seal_points = bytes::read_u64(&mut r)?;
                let seal_age_ns = bytes::read_u64(&mut r)?;
                // A zero-capacity extent can never hold a point, and the
                // capacity drives server-side allocation (see
                // [`MAX_SEAL_POINTS`]): hostile or corrupt, never a real
                // policy.
                if seal_points == 0 || seal_points > MAX_SEAL_POINTS {
                    return Err(CodecError::BadGeometry {
                        items: seal_points,
                        len: 0,
                        dim: params.outer.dim as u64,
                    });
                }
                Ok(Message::BuildLive { node_id, id_base, p, params, seal_points, seal_age_ns })
            }
            TAG_INSERT_BATCH => {
                let seq = bytes::read_u64(&mut r)?;
                let n = bytes::read_u64(&mut r)?;
                if n > MAX_ITEMS as u64 {
                    return Err(CodecError::TooLong(n, MAX_ITEMS as u64));
                }
                let points = bytes::read_f32_vec(&mut r)?;
                let labels = bytes::read_bitvec(&mut r)?;
                // The label count is peer-controlled twice (header `n`
                // and the bitvec's own length): a mismatch means the
                // frame lies about its geometry.
                if labels.len() as u64 != n {
                    return Err(CodecError::BadGeometry {
                        items: n,
                        len: labels.len() as u64,
                        dim: 1,
                    });
                }
                Ok(Message::InsertBatch { seq, n, points, labels })
            }
            TAG_INSERT_ACK => {
                let seq = bytes::read_u64(&mut r)?;
                let accepted = bytes::read_u64(&mut r)?;
                let total = bytes::read_u64(&mut r)?;
                let sealed_now = bytes::read_u64(&mut r)?;
                let sealed_total = bytes::read_u64(&mut r)?;
                // Flags byte: bit 0 must mirror `sealed_now > 0`; unknown
                // bits or an incoherent mirror = hostile/corrupt peer.
                let flags = bytes::read_u8(&mut r)?;
                if flags > 1 || (flags == 1) != (sealed_now > 0) {
                    return Err(CodecError::BadTag(flags as u32, "InsertAckFlags"));
                }
                Ok(Message::InsertAck { seq, accepted, total, sealed_now, sealed_total })
            }
            TAG_HEARTBEAT => Ok(Message::Heartbeat { seq: bytes::read_u64(&mut r)? }),
            TAG_HEARTBEAT_ACK => {
                let seq = bytes::read_u64(&mut r)?;
                let total = bytes::read_u64(&mut r)?;
                let sealed_now = bytes::read_u64(&mut r)?;
                let sealed_total = bytes::read_u64(&mut r)?;
                // Flags byte: bit 0 = live; unknown bits, or a non-live
                // node claiming ingest counters, = hostile/corrupt peer.
                let flags = bytes::read_u8(&mut r)?;
                if flags > 1 || (flags == 0 && total | sealed_now | sealed_total != 0) {
                    return Err(CodecError::BadTag(flags as u32, "HeartbeatAckFlags"));
                }
                Ok(Message::HeartbeatAck { seq, live: flags == 1, total, sealed_now, sealed_total })
            }
            TAG_SHUTDOWN => Ok(Message::Shutdown),
            t => Err(CodecError::BadTag(t as u32, "Message")),
        }
    }

    /// Write as a length-prefixed frame.
    pub fn write_frame<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let payload = self.encode();
        w.write_all(&(payload.len() as u32).to_le_bytes())?;
        w.write_all(&payload)?;
        w.flush()
    }

    /// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
    pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Message>, CodecError> {
        let mut len_buf = [0u8; 4];
        match r.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e.into()),
        }
        let len = u32::from_le_bytes(len_buf);
        if len > MAX_FRAME {
            return Err(CodecError::TooLong(len as u64, MAX_FRAME as u64));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        Ok(Some(Message::decode(&payload)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_dataset() -> Dataset {
        let mut d = Dataset::new("wire-test", 3);
        d.push(&[1.0, 2.0, 3.0], false);
        d.push(&[4.0, 5.0, 6.0], true);
        d
    }

    fn roundtrip(m: &Message) -> Message {
        let mut buf = Vec::new();
        m.write_frame(&mut buf).unwrap();
        let got = Message::read_frame(&mut std::io::Cursor::new(buf)).unwrap().unwrap();
        got
    }

    #[test]
    fn build_roundtrip() {
        let m = Message::Build {
            node_id: 3,
            id_base: 1000,
            p: 8,
            params: SlshParams::paper_onset(30, 20.0, 180.0, 42),
            shard: sample_dataset(),
        };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn query_reply_roundtrip() {
        let q = Message::Query { qid: 9, q: vec![1.5, -2.0, 0.0] };
        assert_eq!(roundtrip(&q), q);
        let r = Message::Reply {
            qid: 9,
            neighbors: vec![
                Neighbor { id: 5, dist: 1.25, label: true },
                Neighbor { id: 11, dist: 3.5, label: false },
            ],
            comparisons: vec![10, 20, 30],
            inner_probes: 4,
        };
        assert_eq!(roundtrip(&r), r);
    }

    /// One of each enforcement-relevant frame shape, spanning lanes,
    /// policies, flags and the no-budget sentinel — the corpus the
    /// roundtrip and truncation property tests sweep.
    fn budget_frame_corpus() -> Vec<Message> {
        let mut frames = Vec::new();
        // Geometry sweep × class × policy × probe knobs for the budget
        // frame (probe pairs sweep baseline, multi-probe, capped, and
        // the extreme legal corners).
        let probe_knobs =
            [(1u32, 0u64), (2, 0), (8, 512), (1, 1), (MAX_PROBES, u64::MAX)];
        for (i, (nq, dim)) in [(1u64, 1usize), (2, 3), (4, 7), (3, 30)].into_iter().enumerate() {
            for class in [Class::Monitor, Class::Analytics] {
                for (j, policy) in
                    [BudgetPolicy::LogOnly, BudgetPolicy::PartialResults, BudgetPolicy::Shed]
                        .into_iter()
                        .enumerate()
                {
                    let (probes, max_comparisons) = probe_knobs[(i + j) % probe_knobs.len()];
                    // Alternate traced / untraced so the sweep covers
                    // both trace-flag states on every geometry.
                    let trace = if (i + j) % 2 == 0 { 0 } else { (i * 100 + j + 1) as u64 };
                    frames.push(Message::QueryBatchBudget {
                        qid0: 77,
                        nq,
                        budget_us: 1500,
                        class,
                        policy,
                        probes,
                        max_comparisons,
                        trace,
                        qs: (0..nq as usize * dim).map(|i| i as f32 * 0.5).collect(),
                    });
                }
            }
        }
        // The no-budget sentinel used by caller-formed blocks — and by
        // budgetless specs that still carry probe knobs.
        frames.push(Message::QueryBatchBudget {
            qid0: 0,
            nq: 1,
            budget_us: u64::MAX,
            class: Class::Analytics,
            policy: BudgetPolicy::LogOnly,
            probes: 4,
            max_comparisons: 2048,
            trace: u64::MAX,
            qs: vec![9.0, 8.0, 7.0],
        });
        // Reply batches across every coherent flag state, empty and
        // non-empty neighbor sets, empty batch included; traced and
        // untraced echoes.
        frames.push(Message::ReplyBatch { qid0: 9, trace: 0, replies: vec![] });
        frames.push(Message::ReplyBatch {
            qid0: 40,
            trace: 12345,
            replies: vec![
                BatchReplyItem {
                    neighbors: vec![Neighbor { id: 5, dist: 1.25, label: true }],
                    comparisons: vec![10, 20],
                    inner_probes: 1,
                    scan_ns: 42_000,
                    tables: 8,
                    partial: false,
                    shed: false,
                },
                BatchReplyItem {
                    neighbors: vec![Neighbor { id: 6, dist: 2.5, label: false }],
                    comparisons: vec![4, 0],
                    inner_probes: 0,
                    scan_ns: u64::MAX,
                    tables: 3,
                    partial: true,
                    shed: false,
                },
                BatchReplyItem {
                    neighbors: vec![],
                    comparisons: vec![0, 0],
                    inner_probes: 0,
                    scan_ns: 0,
                    tables: 0,
                    partial: true,
                    shed: true,
                },
            ],
        });
        frames
    }

    /// The streaming-ingest frames, spanning geometries, label patterns,
    /// seal states and both policy shapes — swept by the same roundtrip
    /// and truncation property tests as the budget frames.
    fn ingest_frame_corpus() -> Vec<Message> {
        let mut frames = Vec::new();
        for (n, dim) in [(1u64, 1usize), (2, 3), (5, 7), (3, 30)] {
            frames.push(Message::InsertBatch {
                seq: 9,
                n,
                points: (0..n as usize * dim).map(|i| i as f32 * 0.25).collect(),
                labels: (0..n as usize).map(|i| i % 2 == 0).collect(),
            });
        }
        // Empty batch: legal (a no-op append), must survive the codec.
        frames.push(Message::InsertBatch { seq: 0, n: 0, points: vec![], labels: vec![] });
        // Acks across both coherent flag states.
        frames.push(Message::InsertAck {
            seq: 9,
            accepted: 5,
            total: 105,
            sealed_now: 0,
            sealed_total: 3,
        });
        frames.push(Message::InsertAck {
            seq: 10,
            accepted: 64,
            total: 169,
            sealed_now: 2,
            sealed_total: 5,
        });
        // Live builds: size-only and size-or-age policies.
        frames.push(Message::BuildLive {
            node_id: 2,
            id_base: 1 << 40,
            p: 4,
            params: SlshParams::paper_onset(30, 20.0, 180.0, 42),
            seal_points: 4096,
            seal_age_ns: u64::MAX,
        });
        frames.push(Message::BuildLive {
            node_id: 0,
            id_base: 0,
            p: 1,
            params: SlshParams::paper_onset(30, 20.0, 180.0, 7),
            seal_points: 128,
            seal_age_ns: 5_000_000,
        });
        frames
    }

    /// The failure-detector frames: probes across seq values, acks from
    /// live nodes (all counter shapes) and batch nodes (all-zero) —
    /// swept by the same roundtrip and truncation property tests.
    fn heartbeat_frame_corpus() -> Vec<Message> {
        let mut frames = Vec::new();
        for seq in [0u64, 1, 7, u64::MAX] {
            frames.push(Message::Heartbeat { seq });
        }
        // Batch node: not live, all counters zero (the only coherent
        // non-live shape).
        frames.push(Message::HeartbeatAck {
            seq: 3,
            live: false,
            total: 0,
            sealed_now: 0,
            sealed_total: 0,
        });
        // Live nodes: quiet, actively sealing, and sealed-in-the-past.
        frames.push(Message::HeartbeatAck {
            seq: 4,
            live: true,
            total: 0,
            sealed_now: 0,
            sealed_total: 0,
        });
        frames.push(Message::HeartbeatAck {
            seq: 5,
            live: true,
            total: 4096,
            sealed_now: 2,
            sealed_total: 9,
        });
        frames.push(Message::HeartbeatAck {
            seq: 6,
            live: true,
            total: 128,
            sealed_now: 0,
            sealed_total: 1,
        });
        frames
    }

    #[test]
    fn batch_messages_roundtrip() {
        let q = Message::QueryBatch { qid0: 40, nq: 2, qs: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        assert_eq!(roundtrip(&q), q);
    }

    #[test]
    fn budget_and_reply_frames_roundtrip_across_sweep() {
        for m in budget_frame_corpus()
            .into_iter()
            .chain(ingest_frame_corpus())
            .chain(heartbeat_frame_corpus())
        {
            assert_eq!(roundtrip(&m), m, "frame {m:?}");
        }
    }

    #[test]
    fn budget_and_reply_frames_reject_truncation_at_every_byte() {
        // Property: EVERY strict prefix of a valid payload must decode to
        // an error — never panic, never silently succeed with less data.
        for m in budget_frame_corpus()
            .into_iter()
            .chain(ingest_frame_corpus())
            .chain(heartbeat_frame_corpus())
        {
            let payload = m.encode();
            assert_eq!(Message::decode(&payload).unwrap(), m);
            for cut in 0..payload.len() {
                assert!(
                    Message::decode(&payload[..cut]).is_err(),
                    "decode must fail at cut {cut}/{} for {m:?}",
                    payload.len()
                );
            }
            // Framed variant: valid length prefix, payload cut short.
            let mut framed = Vec::new();
            m.write_frame(&mut framed).unwrap();
            for cut in 4..framed.len() {
                assert!(
                    Message::read_frame(&mut std::io::Cursor::new(&framed[..cut])).is_err(),
                    "read_frame must fail at cut {cut} for {m:?}"
                );
            }
        }
    }

    #[test]
    fn bad_class_byte_is_rejected() {
        let m = Message::QueryBatchBudget {
            qid0: 1,
            nq: 1,
            budget_us: 100,
            class: Class::Monitor,
            policy: BudgetPolicy::LogOnly,
            probes: 1,
            max_comparisons: 0,
            trace: 0,
            qs: vec![1.0, 2.0],
        };
        let mut payload = m.encode();
        // Payload layout: tag(1) + qid0(8) + nq(8) + budget_us(8) +
        // class(1) + policy(1) + floats. Flip the class byte to an
        // unknown lane.
        assert_eq!(payload[25], Class::Monitor.as_u8());
        payload[25] = 7;
        assert!(matches!(Message::decode(&payload), Err(CodecError::BadTag(7, _))));
        // Round-tripping the class codec itself: both lanes survive,
        // unknown bytes do not.
        for class in [Class::Monitor, Class::Analytics] {
            assert_eq!(Class::from_u8(class.as_u8()), Some(class));
        }
        assert_eq!(Class::from_u8(2), None);
    }

    #[test]
    fn bad_policy_byte_is_rejected() {
        let m = Message::QueryBatchBudget {
            qid0: 1,
            nq: 1,
            budget_us: 100,
            class: Class::Monitor,
            policy: BudgetPolicy::Shed,
            probes: 1,
            max_comparisons: 0,
            trace: 0,
            qs: vec![1.0, 2.0],
        };
        let mut payload = m.encode();
        // Policy byte sits right after the class byte.
        assert_eq!(payload[26], BudgetPolicy::Shed.as_u8());
        for bad in [3u8, 7, 255] {
            payload[26] = bad;
            let got = Message::decode(&payload);
            assert!(
                matches!(got, Err(CodecError::BadTag(b, "BudgetPolicy")) if b == bad as u32),
                "policy byte {bad} must be rejected"
            );
        }
        // The policy codec itself: all three policies survive, unknown
        // bytes do not.
        for policy in [BudgetPolicy::LogOnly, BudgetPolicy::PartialResults, BudgetPolicy::Shed] {
            assert_eq!(BudgetPolicy::from_u8(policy.as_u8()), Some(policy));
        }
        assert_eq!(BudgetPolicy::from_u8(3), None);
    }

    #[test]
    fn bad_probes_field_is_rejected() {
        let m = Message::QueryBatchBudget {
            qid0: 1,
            nq: 1,
            budget_us: 100,
            class: Class::Monitor,
            policy: BudgetPolicy::PartialResults,
            probes: 3,
            max_comparisons: 64,
            trace: 9,
            qs: vec![1.0, 2.0],
        };
        let mut payload = m.encode();
        // Payload layout: tag(1) + qid0(8) + nq(8) + budget_us(8) +
        // class(1) + policy(1) + probes(4) + max_comparisons(8) + floats
        // — the probes u32 sits at bytes 27..31.
        assert_eq!(u32::from_le_bytes(payload[27..31].try_into().unwrap()), 3);
        for hostile in [0u32, MAX_PROBES + 1, u32::MAX] {
            payload[27..31].copy_from_slice(&hostile.to_le_bytes());
            let got = Message::decode(&payload);
            assert!(
                matches!(got, Err(CodecError::BadTag(b, "Probes")) if b == hostile),
                "probes field {hostile} must be rejected"
            );
        }
        // The full legal range survives the codec.
        for probes in [1u32, 2, MAX_PROBES] {
            payload[27..31].copy_from_slice(&probes.to_le_bytes());
            match Message::decode(&payload).unwrap() {
                Message::QueryBatchBudget { probes: got, .. } => assert_eq!(got, probes),
                other => panic!("wrong message: {other:?}"),
            }
        }
    }

    #[test]
    fn bad_reply_flags_byte_is_rejected() {
        let m = Message::ReplyBatch {
            qid0: 4,
            trace: 0,
            replies: vec![BatchReplyItem {
                neighbors: vec![],
                comparisons: vec![1],
                inner_probes: 0,
                scan_ns: 77,
                tables: 2,
                partial: false,
                shed: false,
            }],
        };
        let mut payload = m.encode();
        // The flags byte is the LAST payload byte (single item).
        let last = payload.len() - 1;
        // 2 = shed-without-partial (incoherent), >3 = unknown bits.
        for bad in [2u8, 4, 9, 255] {
            payload[last] = bad;
            let got = Message::decode(&payload);
            assert!(
                matches!(got, Err(CodecError::BadTag(b, "ReplyFlags")) if b == bad as u32),
                "flags byte {bad} must be rejected"
            );
        }
    }

    #[test]
    fn bad_trace_flags_are_rejected_on_both_frames() {
        // Request frame: tag(1) + qid0(8) + nq(8) + budget_us(8) +
        // class(1) + policy(1) + probes(4) + max_comparisons(8) puts the
        // trace flag byte at offset 39 and the id at 40..48.
        let traced = Message::QueryBatchBudget {
            qid0: 1,
            nq: 1,
            budget_us: 100,
            class: Class::Monitor,
            policy: BudgetPolicy::LogOnly,
            probes: 1,
            max_comparisons: 0,
            trace: 0xABCD,
            qs: vec![1.0, 2.0],
        };
        let payload = traced.encode();
        assert_eq!(payload[39], 1);
        assert_eq!(u64::from_le_bytes(payload[40..48].try_into().unwrap()), 0xABCD);
        // Unknown flag bits.
        for bad in [2u8, 5, 255] {
            let mut p = payload.clone();
            p[39] = bad;
            assert!(
                matches!(
                    Message::decode(&p),
                    Err(CodecError::BadTag(b, "TraceFlags")) if b == bad as u32
                ),
                "trace flag byte {bad} must be rejected"
            );
        }
        // Incoherent: flag set, id zero.
        let mut p = payload.clone();
        p[40..48].copy_from_slice(&0u64.to_le_bytes());
        assert!(matches!(Message::decode(&p), Err(CodecError::BadTag(1, "TraceFlags"))));
        // Incoherent: flag clear, id nonzero.
        let mut p = payload.clone();
        p[39] = 0;
        assert!(matches!(Message::decode(&p), Err(CodecError::BadTag(0, "TraceFlags"))));

        // Reply frame: tag(1) + qid0(8) puts the trace flag byte at
        // offset 9 and the id at 10..18.
        let reply = Message::ReplyBatch { qid0: 4, trace: 99, replies: vec![] };
        let payload = reply.encode();
        assert_eq!(payload[9], 1);
        assert_eq!(u64::from_le_bytes(payload[10..18].try_into().unwrap()), 99);
        let mut p = payload.clone();
        p[9] = 3;
        assert!(matches!(Message::decode(&p), Err(CodecError::BadTag(3, "TraceFlags"))));
        let mut p = payload.clone();
        p[10..18].copy_from_slice(&0u64.to_le_bytes());
        assert!(matches!(Message::decode(&p), Err(CodecError::BadTag(1, "TraceFlags"))));
        let mut p = payload;
        p[9] = 0;
        assert!(matches!(Message::decode(&p), Err(CodecError::BadTag(0, "TraceFlags"))));
    }

    #[test]
    fn insert_batch_label_count_mismatch_is_rejected() {
        // Header `n` and the labels bitvec each carry a count; a frame
        // whose counts disagree lies about its geometry.
        let m = Message::InsertBatch {
            seq: 1,
            n: 3,
            points: vec![0.0; 9],
            labels: vec![true, false, true],
        };
        let mut payload = m.encode();
        assert_eq!(Message::decode(&payload).unwrap(), m);
        // Payload layout: tag(1) + seq(8) + n(8) + ... — bump `n` so it
        // no longer matches the shipped labels.
        payload[9] = 4;
        assert!(matches!(
            Message::decode(&payload),
            Err(CodecError::BadGeometry { items: 4, len: 3, .. })
        ));
    }

    #[test]
    fn bad_insert_ack_flags_byte_is_rejected() {
        let m = Message::InsertAck {
            seq: 4,
            accepted: 8,
            total: 80,
            sealed_now: 0,
            sealed_total: 2,
        };
        let mut payload = m.encode();
        let last = payload.len() - 1;
        assert_eq!(payload[last], 0);
        // Unknown bits AND the incoherent "sealed flag without a seal".
        for bad in [1u8, 2, 4, 255] {
            payload[last] = bad;
            let got = Message::decode(&payload);
            assert!(
                matches!(got, Err(CodecError::BadTag(b, "InsertAckFlags")) if b == bad as u32),
                "flags byte {bad} must be rejected"
            );
        }
        // The mirrored incoherence: a seal count without the flag.
        let sealed = Message::InsertAck {
            seq: 4,
            accepted: 8,
            total: 80,
            sealed_now: 1,
            sealed_total: 3,
        };
        let mut payload = sealed.encode();
        let last = payload.len() - 1;
        assert_eq!(payload[last], 1);
        payload[last] = 0;
        assert!(matches!(
            Message::decode(&payload),
            Err(CodecError::BadTag(0, "InsertAckFlags"))
        ));
    }

    #[test]
    fn bad_heartbeat_ack_flags_byte_is_rejected() {
        let m = Message::HeartbeatAck {
            seq: 7,
            live: true,
            total: 64,
            sealed_now: 1,
            sealed_total: 2,
        };
        let mut payload = m.encode();
        let last = payload.len() - 1;
        assert_eq!(payload[last], 1);
        // Unknown bits beyond the live flag.
        for bad in [2u8, 3, 4, 255] {
            payload[last] = bad;
            let got = Message::decode(&payload);
            assert!(
                matches!(got, Err(CodecError::BadTag(b, "HeartbeatAckFlags")) if b == bad as u32),
                "flags byte {bad} must be rejected"
            );
        }
        // The incoherence: a batch (non-live) node claiming ingest
        // counters.
        payload[last] = 0;
        assert!(matches!(
            Message::decode(&payload),
            Err(CodecError::BadTag(0, "HeartbeatAckFlags"))
        ));
    }

    #[test]
    fn build_live_hostile_seal_capacity_is_rejected() {
        let m = Message::BuildLive {
            node_id: 1,
            id_base: 0,
            p: 2,
            params: SlshParams::paper_onset(30, 20.0, 180.0, 3),
            seal_points: 1,
            seal_age_ns: u64::MAX,
        };
        assert_eq!(roundtrip(&m), m);
        // seal_points sits 16 bytes before the payload end (u64 + u64).
        let mut payload = m.encode();
        let at = payload.len() - 16;
        for hostile in [0u64, MAX_ITEMS as u64 + 1, u64::MAX] {
            payload[at..at + 8].copy_from_slice(&hostile.to_le_bytes());
            assert!(
                matches!(
                    Message::decode(&payload),
                    Err(CodecError::BadGeometry { .. }) | Err(CodecError::TooLong(..))
                ),
                "seal_points {hostile} must be rejected"
            );
        }
    }

    #[test]
    fn batch_geometry_validation() {
        // Accepts rectangular blocks (including the empty batch).
        assert_eq!(validate_batch_geometry(4, 12, 3).unwrap(), 4);
        assert_eq!(validate_batch_geometry(0, 0, 3).unwrap(), 0);
        // Mismatched float count: off by one either way.
        assert!(matches!(
            validate_batch_geometry(4, 11, 3),
            Err(CodecError::BadGeometry { items: 4, len: 11, dim: 3 })
        ));
        assert!(matches!(
            validate_batch_geometry(4, 13, 3),
            Err(CodecError::BadGeometry { .. })
        ));
        // Zero dimension can never form a valid batch.
        assert!(matches!(
            validate_batch_geometry(1, 0, 0),
            Err(CodecError::BadGeometry { .. })
        ));
        // Oversized count: rejected by the sanity cap before any multiply.
        assert!(matches!(
            validate_batch_geometry(MAX_ITEMS as u64 + 1, 30, 30),
            Err(CodecError::TooLong(..))
        ));
        // Hostile count that would overflow nq * dim on 64-bit is caught
        // by the cap; a count just inside the cap with a huge implied
        // payload still fails the equality check.
        assert!(matches!(
            validate_batch_geometry(u64::MAX, 30, 30),
            Err(CodecError::TooLong(..))
        ));
        assert!(matches!(
            validate_batch_geometry(MAX_ITEMS as u64, 30, usize::MAX),
            Err(CodecError::BadGeometry { .. }) | Err(CodecError::TooLong(..))
        ));
    }

    #[test]
    fn lifecycle_messages_roundtrip() {
        let d = Message::BuildDone { node_id: 1, shard_len: 500, build_ms: 12.5 };
        assert_eq!(roundtrip(&d), d);
        assert_eq!(roundtrip(&Message::Shutdown), Message::Shutdown);
    }

    #[test]
    fn clean_eof_is_none() {
        let empty: &[u8] = &[];
        assert!(Message::read_frame(&mut std::io::Cursor::new(empty)).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_is_error() {
        let mut buf = Vec::new();
        Message::Shutdown.write_frame(&mut buf).unwrap();
        buf.truncate(buf.len() - 1); // valid length prefix, short payload
        let mut long = Vec::new();
        Message::Query { qid: 1, q: vec![1.0; 64] }.write_frame(&mut long).unwrap();
        long.truncate(20);
        assert!(Message::read_frame(&mut std::io::Cursor::new(long)).is_err());
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(matches!(Message::decode(&[99]), Err(CodecError::BadTag(99, _))));
    }
}
