//! HTTP/JSON serving edge: the cluster's front door.
//!
//! [`EdgeServer`] accepts plain HTTP/1.1 connections (std `TcpListener`,
//! zero dependencies — parsing lives in [`crate::net::http`]), validates
//! typed JSON request bodies over [`crate::util::json`], and maps them
//! onto the Orchestrator's admission lanes:
//!
//! * `POST /v1/query` — `{"point": [f32; dim], "class"?: "monitor" |
//!   "analytics", "budget_us"?: u64, "policy"?: "log_only" | "partial" |
//!   "shed", "probes"?: u32, "recall_hint"?: f32 in (0,1],
//!   "max_comparisons"?: u64, "k"?: usize}`. The body is one
//!   [`QuerySpec`] in JSON clothing: every knob the typed API accepts
//!   rides the wire, and the edge pre-validates the combination
//!   ([`QuerySpec::validate`]) so a contradictory spec (`probes` +
//!   `recall_hint` together, out-of-range hint) is a typed `400` at the
//!   boundary, never a panic in the cluster. With the admission layer
//!   installed the edge calls `try_submit_spec`, so a full queue is a
//!   `429` with `Retry-After` (backpressure is part of the API
//!   contract); a request-level `"policy"` can tighten — never loosen —
//!   the cut policy fixed by the installed [`AdmissionConfig`]. Without
//!   admission, the edge drives `query_spec` directly; for backward
//!   compatibility a `"budget_us"` without `"policy"` enforces
//!   `log_only`, exactly as the pre-spec edge did. A budget-blown answer
//!   (`QueryResult::partial`) comes back as `206` with `"partial":true`
//!   and `"shed_nodes"` — degraded, flagged, never silent.
//! * `POST /v1/insert` — `{"points": [[f32; dim]..], "labels": [bool..],
//!   "class"?}` → [`Orchestrator::insert_batch_class`]; a zero-ack insert
//!   (`ClusterError::ShardUnavailable`) is `503`, and the response body
//!   reports `replicas_acked` so under-replicated writes are visible.
//! * `GET /v1/stats` — edge / admission / ingest / failover counters in
//!   one JSON document, including the accuracy/latency tradeoff
//!   telemetry: per-lane effective probe counts, the EWMA of comparisons
//!   per query, and whether the feedback controller
//!   ([`AutoProbes`](crate::coordinator::admission::AutoProbes)) is
//!   driving them.
//! * `GET /healthz` — process liveness (always `200` while serving).
//! * `GET /readyz` — cluster readiness: `200` only while the PR 6
//!   failure detector reports every replica reachable
//!   (`FailoverStats::replicas_down == 0`), else `503` — so a load
//!   balancer stops routing to an edge whose cluster is degraded.
//! * `GET /metrics` — the same counters (plus the tracing subsystem's
//!   per-lane queue-wait / service / e2e and per-shard network / scan
//!   histograms, and the per-cause dropped-input counters) in Prometheus
//!   text exposition format: one scrape covers every stats family the
//!   edge knows about.
//! * `GET /v1/debug/slow` — the tracer's bounded slow-query ring as
//!   JSON: per-stage spans and per-shard scan summaries of recent
//!   slow / partial / shed / hedged requests (requires span collection,
//!   [`Tracer::set_collect`]).
//!
//! Time is injected: the read deadline (slowloris cut-off) and the
//! per-request latency counters run on the [`Clock`] handed to
//! [`EdgeServer::start_with_clock`], so the whole edge is deterministic
//! under a `MockClock` in tests and `SystemClock` in production. The
//! status-code ↔ cluster-semantics table lives in [`crate::net::http`].

use std::io::{Read as _, Write as _};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{
    AdmissionError, AdmissionStats, BudgetPolicy, Class, ClusterError, LaneStats, Orchestrator,
    QueryResult, QuerySpec,
};
use crate::net::http::{parse_request, HttpError, Limits, Request, Response};
use crate::runtime::hist::{bucket_upper_bound, HistSnapshot, NUM_BUCKETS};
use crate::runtime::service::{
    decode_reject_counts, EdgeCounters, EdgeEndpoint, EdgeStats, EndpointStats, FailoverStats,
    IngestStats,
};
use crate::runtime::trace::{Tracer, LANE_NAMES, NUM_LANES};
use crate::util::clock::{Clock, SystemClock};
use crate::util::json::{Json, JsonObj};

/// Serving-edge tunables. `dim` must match the cluster: the edge
/// pre-validates point dimension so a wrong-sized query is a typed `400`
/// at the boundary instead of an assertion deep in the admission layer.
#[derive(Debug, Clone)]
pub struct EdgeConfig {
    /// Query/insert point dimensionality (the cluster's `dim`).
    pub dim: usize,
    /// HTTP parser caps (head/header-count/body).
    pub limits: Limits,
    /// Total time a client gets to deliver one full request, measured on
    /// the injected clock (slowloris cut-off → `408`).
    pub read_timeout: Duration,
    /// OS-level poll interval while waiting for request bytes: the real
    /// `set_read_timeout` on the socket, after which the deadline is
    /// re-checked on the injected clock.
    pub read_poll: Duration,
    /// Seconds advertised in `Retry-After` on a `429`.
    pub retry_after_s: u32,
    /// Budget assigned to queries that do not send `"budget_us"` when the
    /// admission layer is installed (the queue needs a deadline to
    /// schedule by; the default is long enough to behave as "no
    /// deadline").
    pub default_budget: Duration,
}

impl EdgeConfig {
    pub fn new(dim: usize) -> EdgeConfig {
        EdgeConfig {
            dim,
            limits: Limits::default(),
            read_timeout: Duration::from_secs(2),
            read_poll: Duration::from_millis(5),
            retry_after_s: 1,
            default_budget: Duration::from_secs(3600),
        }
    }

    pub fn with_limits(mut self, limits: Limits) -> EdgeConfig {
        self.limits = limits;
        self
    }

    pub fn with_read_timeout(mut self, timeout: Duration) -> EdgeConfig {
        self.read_timeout = timeout;
        self
    }

    pub fn with_retry_after_s(mut self, s: u32) -> EdgeConfig {
        self.retry_after_s = s;
        self
    }

    pub fn with_default_budget(mut self, budget: Duration) -> EdgeConfig {
        self.default_budget = budget;
        self
    }
}

struct Shared {
    orch: Arc<Orchestrator>,
    cfg: EdgeConfig,
    clock: Arc<dyn Clock>,
    counters: EdgeCounters,
    handlers: Mutex<Vec<JoinHandle<()>>>,
}

/// The HTTP front door: an accept loop plus one short-lived handler
/// thread per connection (the edge speaks one request per connection —
/// see [`crate::net::http`]). Dropping the server stops accepting, wakes
/// the accept thread and joins every in-flight handler.
pub struct EdgeServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl EdgeServer {
    /// Serve `orch` on `listener` with the production clock.
    pub fn start(
        orch: Arc<Orchestrator>,
        listener: TcpListener,
        cfg: EdgeConfig,
    ) -> std::io::Result<EdgeServer> {
        EdgeServer::start_with_clock(orch, listener, cfg, Arc::new(SystemClock::new()))
    }

    /// Serve with an injected clock — tests drive read deadlines and
    /// latency accounting with a `MockClock` (no sleeps).
    pub fn start_with_clock(
        orch: Arc<Orchestrator>,
        listener: TcpListener,
        cfg: EdgeConfig,
        clock: Arc<dyn Clock>,
    ) -> std::io::Result<EdgeServer> {
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            orch,
            cfg,
            clock,
            counters: EdgeCounters::new(),
            handlers: Mutex::new(Vec::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new().name("edge-accept".into()).spawn(move || loop {
                let stream = match listener.accept() {
                    Ok((s, _)) => s,
                    Err(_) => {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        continue;
                    }
                };
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let sh = Arc::clone(&shared);
                let handle = std::thread::spawn(move || handle_conn(&sh, stream));
                let mut hs = shared.handlers.lock().unwrap();
                hs.retain(|h| !h.is_finished());
                hs.push(handle);
            })?
        };
        Ok(EdgeServer { shared, addr, stop, accept: Some(accept) })
    }

    /// The bound address (port 0 in tests resolves here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Per-endpoint request/error/latency counters.
    pub fn stats(&self) -> EdgeStats {
        self.shared.counters.snapshot()
    }
}

impl Drop for EdgeServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the accept loop with a throwaway connection; it re-checks
        // the stop flag before handling anything.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let handlers = std::mem::take(&mut *self.shared.handlers.lock().unwrap());
        for h in handlers {
            let _ = h.join();
        }
    }
}

fn handle_conn(sh: &Shared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(sh.cfg.read_poll));
    let start_ns = sh.clock.now_ns();
    let deadline_ns = start_ns.saturating_add(sh.cfg.read_timeout.as_nanos() as u64);
    let (endpoint, resp) =
        match parse_request(&mut stream, sh.clock.as_ref(), deadline_ns, &sh.cfg.limits) {
            Ok(req) => route(sh, &req),
            Err(e) => {
                // A parser 4xx used to vanish into `other.errors` with no
                // cause: count it by the typed error code so `/metrics`
                // can say WHY inputs are being turned away.
                sh.counters.record_http_reject(e.code);
                (EdgeEndpoint::Other, Response::from_err(&e))
            }
        };
    let status = resp.status;
    let _ = resp.write_to(&mut stream);
    let _ = stream.flush();
    // Lingering close: signal end-of-response, then drain (bounded) what
    // the client is still sending, so an early error response — e.g. a
    // 431 cut mid-upload — isn't destroyed by a TCP reset before the
    // client reads it.
    let _ = stream.shutdown(Shutdown::Write);
    let mut sink = [0u8; 4096];
    for _ in 0..256 {
        match stream.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => continue,
        }
    }
    let latency_us = sh.clock.now_ns().saturating_sub(start_ns) / 1_000;
    sh.counters.record(endpoint, status, latency_us);
}

fn route(sh: &Shared, req: &Request) -> (EdgeEndpoint, Response) {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/query") => (EdgeEndpoint::Query, handle_query(sh, req)),
        ("POST", "/v1/insert") => (EdgeEndpoint::Insert, handle_insert(sh, req)),
        ("GET", "/v1/stats") => (EdgeEndpoint::Stats, handle_stats(sh)),
        ("GET", "/healthz") => (EdgeEndpoint::Health, handle_healthz()),
        ("GET", "/readyz") => (EdgeEndpoint::Health, handle_readyz(sh)),
        ("GET", "/metrics") => (EdgeEndpoint::Metrics, handle_metrics(sh)),
        ("GET", "/v1/debug/slow") => (EdgeEndpoint::Metrics, handle_slow(sh)),
        (_, "/v1/query") => (EdgeEndpoint::Query, method_not_allowed("POST")),
        (_, "/v1/insert") => (EdgeEndpoint::Insert, method_not_allowed("POST")),
        (_, "/v1/stats") => (EdgeEndpoint::Stats, method_not_allowed("GET")),
        (_, "/healthz" | "/readyz") => (EdgeEndpoint::Health, method_not_allowed("GET")),
        (_, "/metrics" | "/v1/debug/slow") => (EdgeEndpoint::Metrics, method_not_allowed("GET")),
        _ => (EdgeEndpoint::Other, Response::error(404, "not-found", "unknown path")),
    }
}

fn method_not_allowed(allow: &str) -> Response {
    Response::error(405, "method-not-allowed", &format!("use {allow} for this path"))
        .with_header("Allow", allow)
}

// ---------------------------------------------------------------------------
// POST /v1/query
// ---------------------------------------------------------------------------

/// The decoded `POST /v1/query` body: the point plus a [`QuerySpec`]'s
/// worth of knobs, kept apart so the edge can apply its own defaulting
/// (`default_budget` on the admission path, `log_only` on the direct
/// path) before the spec crosses into the cluster.
struct QueryBody {
    point: Vec<f32>,
    spec: QuerySpec,
    has_budget: bool,
    has_policy: bool,
}

fn handle_query(sh: &Shared, req: &Request) -> Response {
    let body = match parse_body(req).and_then(|b| parse_query_body(&b, sh.cfg.dim)) {
        Ok(s) => s,
        Err(e) => return Response::from_err(&e),
    };
    // Pre-validate: the typed entry points treat an invalid spec as a
    // caller bug (they panic); the HTTP boundary turns it into a 400.
    if let Err(msg) = body.spec.validate() {
        return Response::error(400, "bad-spec", &msg);
    }
    if let Some(queue) = sh.orch.admission() {
        // Admission lane path: backpressure (429) and queue-side budget
        // enforcement. The queue schedules by deadline, so a budgetless
        // request is given the configured default budget — same contract
        // as the pre-spec edge.
        let mut spec = body.spec;
        if !body.has_budget {
            spec = spec.with_budget(sh.cfg.default_budget);
        }
        match queue.try_submit_spec(&body.point, &spec).and_then(|ticket| ticket.wait()) {
            Ok(r) => query_result_response(&r),
            Err(e) => admission_error_response(&e, sh.cfg.retry_after_s),
        }
    } else {
        // Direct path (admission disabled): the request's knobs form the
        // node-side Budget/ProbeSpec verbatim. A budget without an
        // explicit policy enforces log_only (observe, don't cut) — the
        // pre-spec edge default.
        let mut spec = body.spec;
        if body.has_budget && !body.has_policy {
            spec = spec.with_policy(BudgetPolicy::LogOnly);
        }
        match sh.orch.query_spec(&body.point, &spec) {
            Ok(r) => query_result_response(&r),
            Err(e) => cluster_error_response(&e),
        }
    }
}

fn admission_error_response(e: &AdmissionError, retry_after_s: u32) -> Response {
    match e {
        AdmissionError::QueueFull => Response::error(
            429,
            "queue-full",
            "admission queue at capacity; retry after the indicated delay",
        )
        .with_header("Retry-After", retry_after_s.to_string()),
        AdmissionError::ShuttingDown => {
            Response::error(503, "shutting-down", "cluster is shutting down")
        }
        AdmissionError::Canceled => {
            Response::error(503, "canceled", "request canceled during cluster teardown")
        }
        AdmissionError::Cluster(c) => cluster_error_response(c),
    }
}

fn cluster_error_response(e: &ClusterError) -> Response {
    match e {
        ClusterError::Shutdown => Response::error(503, "shutting-down", "cluster is shutting down"),
        ClusterError::ShardUnavailable { shard } => Response::error(
            503,
            "shard-unavailable",
            &format!("shard {shard} has no live replica"),
        ),
    }
}

fn parse_query_body(body: &Json, dim: usize) -> Result<QueryBody, HttpError> {
    let obj = top_object(body)?;
    reject_unknown_fields(
        obj,
        &["point", "class", "budget_us", "policy", "probes", "recall_hint", "max_comparisons", "k"],
    )?;
    let point = parse_point(
        obj.get("point")
            .ok_or_else(|| HttpError::new(400, "missing-field", "\"point\" is required"))?,
        dim,
    )?;
    let mut spec = QuerySpec::new();
    if let Some(v) = obj.get("class") {
        spec = spec.with_class(parse_class(v)?);
    }
    let mut has_budget = false;
    if let Some(v) = obj.get("budget_us") {
        let us = v.as_u64().ok_or_else(|| {
            HttpError::new(400, "bad-budget", "\"budget_us\" must be a non-negative integer")
        })?;
        spec = spec.with_budget(Duration::from_micros(us));
        has_budget = true;
    }
    let mut has_policy = false;
    if let Some(v) = obj.get("policy") {
        spec = spec.with_policy(parse_policy(v)?);
        has_policy = true;
    }
    if let Some(v) = obj.get("probes") {
        let p = v.as_u64().filter(|&p| p <= u64::from(u32::MAX)).ok_or_else(|| {
            HttpError::new(400, "bad-probes", "\"probes\" must be an unsigned 32-bit integer")
        })?;
        spec = spec.with_probes(p as u32);
    }
    if let Some(v) = obj.get("recall_hint") {
        let h = v.as_f64().ok_or_else(|| {
            HttpError::new(400, "bad-recall-hint", "\"recall_hint\" must be a number in (0, 1]")
        })?;
        spec = spec.with_recall_hint(h as f32);
    }
    if let Some(v) = obj.get("max_comparisons") {
        let c = v.as_u64().ok_or_else(|| {
            HttpError::new(
                400,
                "bad-max-comparisons",
                "\"max_comparisons\" must be a non-negative integer",
            )
        })?;
        spec = spec.with_max_comparisons(c);
    }
    if let Some(v) = obj.get("k") {
        let k = v.as_u64().ok_or_else(|| {
            HttpError::new(400, "bad-k", "\"k\" must be a non-negative integer")
        })?;
        spec = spec.with_k(k as usize);
    }
    Ok(QueryBody { point, spec, has_budget, has_policy })
}

// ---------------------------------------------------------------------------
// POST /v1/insert
// ---------------------------------------------------------------------------

fn handle_insert(sh: &Shared, req: &Request) -> Response {
    let (flat, labels, class) =
        match parse_body(req).and_then(|b| parse_insert_spec(&b, sh.cfg.dim)) {
            Ok(s) => s,
            Err(e) => return Response::from_err(&e),
        };
    match sh.orch.insert_batch_class(&flat, &labels, class) {
        Ok(out) => {
            let mut o = JsonObj::new();
            o.insert("node", num(out.node as u64));
            o.insert("accepted", num(out.accepted));
            o.insert("node_total", num(out.node_total));
            o.insert("sealed_now", num(out.sealed_now));
            o.insert("sealed_total", num(out.sealed_total));
            o.insert("replicas_acked", num(out.replicas_acked as u64));
            Response::json(200, Json::Obj(o).to_string_compact())
        }
        Err(e) => cluster_error_response(&e),
    }
}

type InsertSpec = (Vec<f32>, Vec<bool>, Class);

fn parse_insert_spec(body: &Json, dim: usize) -> Result<InsertSpec, HttpError> {
    let obj = top_object(body)?;
    reject_unknown_fields(obj, &["points", "labels", "class"])?;
    let points = obj
        .get("points")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| HttpError::new(400, "bad-points", "\"points\" must be an array of points"))?;
    let labels_json = obj
        .get("labels")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| HttpError::new(400, "bad-labels", "\"labels\" must be an array of bools"))?;
    if points.is_empty() {
        return Err(HttpError::new(400, "empty-batch", "insert batch must be non-empty"));
    }
    if points.len() != labels_json.len() {
        return Err(HttpError::new(
            400,
            "length-mismatch",
            format!("{} points but {} labels", points.len(), labels_json.len()),
        ));
    }
    let mut flat = Vec::with_capacity(points.len() * dim);
    for p in points {
        flat.extend_from_slice(&parse_point(p, dim)?);
    }
    let mut labels = Vec::with_capacity(labels_json.len());
    for l in labels_json {
        labels.push(l.as_bool().ok_or_else(|| {
            HttpError::new(400, "bad-labels", "\"labels\" entries must be booleans")
        })?);
    }
    let class = match obj.get("class") {
        Some(v) => parse_class(v)?,
        None => Class::Monitor,
    };
    Ok((flat, labels, class))
}

// ---------------------------------------------------------------------------
// GET /v1/stats, /healthz, /readyz
// ---------------------------------------------------------------------------

fn handle_stats(sh: &Shared) -> Response {
    let mut top = JsonObj::new();
    top.insert("edge", edge_json(&sh.counters.snapshot()));
    top.insert(
        "admission",
        match sh.orch.admission() {
            Some(q) => admission_json(&q.stats()),
            None => Json::Null,
        },
    );
    top.insert("ingest", ingest_json(&sh.orch.ingest_stats()));
    top.insert("failover", failover_json(&sh.orch.failover_stats()));
    Response::json(200, Json::Obj(top).to_string_compact())
}

fn handle_healthz() -> Response {
    let mut o = JsonObj::new();
    o.insert("status", Json::Str("ok".into()));
    Response::json(200, Json::Obj(o).to_string_compact())
}

fn handle_readyz(sh: &Shared) -> Response {
    let down = sh.orch.failover_stats().replicas_down;
    if down == 0 {
        let mut o = JsonObj::new();
        o.insert("ready", Json::Bool(true));
        o.insert("replicas_down", num(0));
        Response::json(200, Json::Obj(o).to_string_compact())
    } else {
        Response::error(503, "not-ready", &format!("{down} replica(s) down"))
    }
}

// ---------------------------------------------------------------------------
// GET /metrics, /v1/debug/slow
// ---------------------------------------------------------------------------

fn handle_metrics(sh: &Shared) -> Response {
    Response::metrics_text(200, prometheus_metrics(sh))
}

fn handle_slow(sh: &Shared) -> Response {
    Response::json(200, sh.orch.tracer().slow_json().to_string_compact())
}

/// One histogram family in text exposition format: cumulative
/// `_bucket{le=...}` rows up to the last non-empty bucket (sparse
/// cumulative buckets are legal and keep 64-bucket histograms readable),
/// then `+Inf`, `_sum` and `_count`. `labels` must be non-empty.
fn prom_histogram(out: &mut String, name: &str, labels: &str, h: &HistSnapshot) {
    use std::fmt::Write as _;
    let last = (0..NUM_BUCKETS).rev().find(|&i| h.buckets[i] > 0);
    let mut cum = 0u64;
    if let Some(last) = last {
        for i in 0..=last {
            cum += h.buckets[i];
            let _ = writeln!(
                out,
                "{name}_bucket{{{labels},le=\"{}\"}} {cum}",
                bucket_upper_bound(i)
            );
        }
    }
    let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {}", h.count);
    let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum);
    let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count);
}

fn prom_type(out: &mut String, name: &str, kind: &str) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn prom_val(out: &mut String, name: &str, labels: &str, v: u64) {
    use std::fmt::Write as _;
    if labels.is_empty() {
        let _ = writeln!(out, "{name} {v}");
    } else {
        let _ = writeln!(out, "{name}{{{labels}}} {v}");
    }
}

/// Render EVERY stats family the edge knows about — per-endpoint edge
/// counters + latency histograms, admission queue/cut/lane counters,
/// ingest, failover, the tracer's per-lane and per-shard histograms, and
/// the per-cause dropped-input counters — as one Prometheus scrape.
fn prometheus_metrics(sh: &Shared) -> String {
    let mut out = String::with_capacity(16 * 1024);

    // --- serving edge, per endpoint ---
    let es = sh.counters.snapshot();
    let endpoints: [(&str, &EndpointStats); 6] = [
        ("query", &es.query),
        ("insert", &es.insert),
        ("stats", &es.stats),
        ("health", &es.health),
        ("metrics", &es.metrics),
        ("other", &es.other),
    ];
    prom_type(&mut out, "dslsh_edge_requests_total", "counter");
    for (name, e) in endpoints {
        prom_val(&mut out, "dslsh_edge_requests_total", &format!("endpoint=\"{name}\""), e.requests);
    }
    prom_type(&mut out, "dslsh_edge_errors_total", "counter");
    for (name, e) in endpoints {
        prom_val(&mut out, "dslsh_edge_errors_total", &format!("endpoint=\"{name}\""), e.errors);
    }
    prom_type(&mut out, "dslsh_edge_latency_us", "histogram");
    for (name, e) in endpoints {
        prom_histogram(
            &mut out,
            "dslsh_edge_latency_us",
            &format!("endpoint=\"{name}\""),
            &e.latency_us,
        );
    }

    // --- admission queue + cuts + lanes (when installed) ---
    if let Some(q) = sh.orch.admission() {
        let s = q.stats();
        prom_type(&mut out, "dslsh_admission_depth", "gauge");
        prom_val(&mut out, "dslsh_admission_depth", "", s.depth as u64);
        prom_type(&mut out, "dslsh_admission_high_water", "gauge");
        prom_val(&mut out, "dslsh_admission_high_water", "", s.high_water as u64);
        prom_type(&mut out, "dslsh_admission_submitted_total", "counter");
        prom_val(&mut out, "dslsh_admission_submitted_total", "", s.submitted);
        prom_type(&mut out, "dslsh_admission_completed_total", "counter");
        prom_val(&mut out, "dslsh_admission_completed_total", "", s.completed);
        prom_type(&mut out, "dslsh_admission_rejected_full_total", "counter");
        prom_val(&mut out, "dslsh_admission_rejected_full_total", "", s.rejected_full);
        prom_type(&mut out, "dslsh_admission_cuts_total", "counter");
        for (reason, v) in [
            ("fill", s.cuts_fill),
            ("deadline", s.cuts_deadline),
            ("aged", s.cuts_aged),
            ("drain", s.cuts_drain),
        ] {
            prom_val(&mut out, "dslsh_admission_cuts_total", &format!("reason=\"{reason}\""), v);
        }
        let lanes = [("monitor", &s.monitor), ("analytics", &s.analytics)];
        prom_type(&mut out, "dslsh_lane_depth", "gauge");
        for (lane, l) in lanes {
            prom_val(&mut out, "dslsh_lane_depth", &format!("lane=\"{lane}\""), l.depth as u64);
        }
        prom_type(&mut out, "dslsh_lane_submitted_total", "counter");
        for (lane, l) in lanes {
            prom_val(&mut out, "dslsh_lane_submitted_total", &format!("lane=\"{lane}\""), l.submitted);
        }
        prom_type(&mut out, "dslsh_lane_dispatched_total", "counter");
        for (lane, l) in lanes {
            for (reason, v) in [
                ("fill", l.dispatched_fill),
                ("deadline", l.dispatched_deadline),
                ("aged", l.dispatched_aged),
                ("drain", l.dispatched_drain),
            ] {
                prom_val(
                    &mut out,
                    "dslsh_lane_dispatched_total",
                    &format!("lane=\"{lane}\",reason=\"{reason}\""),
                    v,
                );
            }
        }
        prom_type(&mut out, "dslsh_lane_overruns_total", "counter");
        for (lane, l) in lanes {
            prom_val(&mut out, "dslsh_lane_overruns_total", &format!("lane=\"{lane}\""), l.overruns);
        }
        prom_type(&mut out, "dslsh_lane_partials_total", "counter");
        for (lane, l) in lanes {
            prom_val(&mut out, "dslsh_lane_partials_total", &format!("lane=\"{lane}\""), l.partials);
        }
        prom_type(&mut out, "dslsh_lane_sheds_total", "counter");
        for (lane, l) in lanes {
            prom_val(&mut out, "dslsh_lane_sheds_total", &format!("lane=\"{lane}\""), l.sheds);
        }
        prom_type(&mut out, "dslsh_lane_inserted_total", "counter");
        for (lane, l) in lanes {
            prom_val(&mut out, "dslsh_lane_inserted_total", &format!("lane=\"{lane}\""), l.inserted);
        }
        prom_type(&mut out, "dslsh_lane_rejected_full_total", "counter");
        for (lane, l) in lanes {
            prom_val(
                &mut out,
                "dslsh_lane_rejected_full_total",
                &format!("lane=\"{lane}\""),
                l.rejected_full,
            );
        }
        prom_type(&mut out, "dslsh_lane_probes", "gauge");
        for (lane, l) in lanes {
            prom_val(&mut out, "dslsh_lane_probes", &format!("lane=\"{lane}\""), u64::from(l.probes));
        }
        prom_type(&mut out, "dslsh_lane_ewma_comparisons", "gauge");
        for (lane, l) in lanes {
            prom_val(
                &mut out,
                "dslsh_lane_ewma_comparisons",
                &format!("lane=\"{lane}\""),
                l.ewma_comparisons,
            );
        }
    }

    // --- ingest ---
    let ing = sh.orch.ingest_stats();
    prom_type(&mut out, "dslsh_ingest_batches_total", "counter");
    prom_val(&mut out, "dslsh_ingest_batches_total", "", ing.batches);
    prom_type(&mut out, "dslsh_ingest_points_total", "counter");
    prom_val(&mut out, "dslsh_ingest_points_total", "", ing.points);
    prom_type(&mut out, "dslsh_ingest_sealed_segments", "gauge");
    prom_val(&mut out, "dslsh_ingest_sealed_segments", "", ing.sealed_segments);

    // --- failover ---
    let f = sh.orch.failover_stats();
    for (name, v) in [
        ("dslsh_failover_hedges_total", f.hedges),
        ("dslsh_failover_hedge_wins_total", f.hedge_wins),
        ("dslsh_failover_failovers_total", f.failovers),
        ("dslsh_failover_synthesized_sheds_total", f.synthesized_sheds),
        ("dslsh_failover_heartbeats_total", f.heartbeats),
        ("dslsh_failover_reconnect_attempts_total", f.reconnect_attempts),
        ("dslsh_failover_reconnects_total", f.reconnects),
        ("dslsh_failover_down_transitions_total", f.down_transitions),
    ] {
        prom_type(&mut out, name, "counter");
        prom_val(&mut out, name, "", v);
    }
    prom_type(&mut out, "dslsh_replicas_down", "gauge");
    prom_val(&mut out, "dslsh_replicas_down", "", f.replicas_down);

    // --- tracing: per-lane stage + per-shard network/scan histograms ---
    let tracer: Arc<Tracer> = sh.orch.tracer();
    prom_type(&mut out, "dslsh_lane_queue_wait_us", "histogram");
    prom_type(&mut out, "dslsh_lane_service_us", "histogram");
    prom_type(&mut out, "dslsh_lane_e2e_us", "histogram");
    for lane in 0..NUM_LANES {
        let h = tracer.lane_hists(lane);
        let labels = format!("lane=\"{}\"", LANE_NAMES[lane]);
        prom_histogram(&mut out, "dslsh_lane_queue_wait_us", &labels, &h.queue_wait_us);
        prom_histogram(&mut out, "dslsh_lane_service_us", &labels, &h.service_us);
        prom_histogram(&mut out, "dslsh_lane_e2e_us", &labels, &h.e2e_us);
    }
    prom_type(&mut out, "dslsh_shard_net_us", "histogram");
    prom_type(&mut out, "dslsh_shard_scan_us", "histogram");
    for shard in 0..tracer.num_shards() {
        let h = tracer.shard_hists(shard);
        let labels = format!("shard=\"{shard}\"");
        prom_histogram(&mut out, "dslsh_shard_net_us", &labels, &h.net_us);
        prom_histogram(&mut out, "dslsh_shard_scan_us", &labels, &h.scan_us);
    }

    // --- silently-dropped input accounting, by cause ---
    prom_type(&mut out, "dslsh_tcp_decode_rejects_total", "counter");
    for (kind, v) in decode_reject_counts() {
        prom_val(&mut out, "dslsh_tcp_decode_rejects_total", &format!("kind=\"{kind}\""), v);
    }
    prom_type(&mut out, "dslsh_http_rejects_total", "counter");
    for (code, v) in sh.counters.http_reject_counts() {
        prom_val(&mut out, "dslsh_http_rejects_total", &format!("code=\"{code}\""), v);
    }
    out
}

// ---------------------------------------------------------------------------
// JSON helpers
// ---------------------------------------------------------------------------

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn parse_body(req: &Request) -> Result<Json, HttpError> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| HttpError::new(400, "body-not-utf8", "request body is not valid UTF-8"))?;
    Json::parse(text).map_err(|e| {
        HttpError::new(400, "bad-json", format!("JSON error at byte {}: {}", e.offset, e.msg))
    })
}

fn top_object(body: &Json) -> Result<&JsonObj, HttpError> {
    body.as_obj()
        .ok_or_else(|| HttpError::new(400, "schema", "request body must be a JSON object"))
}

fn reject_unknown_fields(obj: &JsonObj, allowed: &[&str]) -> Result<(), HttpError> {
    for (k, _) in obj.iter() {
        if !allowed.contains(&k.as_str()) {
            return Err(HttpError::new(
                400,
                "unknown-field",
                format!("unknown field {k:?} (expected one of {allowed:?})"),
            ));
        }
    }
    Ok(())
}

fn parse_point(v: &Json, dim: usize) -> Result<Vec<f32>, HttpError> {
    let arr = v
        .as_arr()
        .ok_or_else(|| HttpError::new(400, "bad-point", "a point must be an array of numbers"))?;
    if arr.len() != dim {
        return Err(HttpError::new(
            400,
            "bad-dimension",
            format!("expected {dim} components, got {}", arr.len()),
        ));
    }
    arr.iter()
        .map(|x| {
            x.as_f64().map(|f| f as f32).ok_or_else(|| {
                HttpError::new(400, "bad-point", "point components must be numbers")
            })
        })
        .collect()
}

fn parse_class(v: &Json) -> Result<Class, HttpError> {
    match v.as_str() {
        Some("monitor") => Ok(Class::Monitor),
        Some("analytics") => Ok(Class::Analytics),
        _ => Err(HttpError::new(
            400,
            "bad-class",
            "\"class\" must be \"monitor\" or \"analytics\"",
        )),
    }
}

fn parse_policy(v: &Json) -> Result<BudgetPolicy, HttpError> {
    match v.as_str() {
        Some("log_only") => Ok(BudgetPolicy::LogOnly),
        Some("partial") => Ok(BudgetPolicy::PartialResults),
        Some("shed") => Ok(BudgetPolicy::Shed),
        _ => Err(HttpError::new(
            400,
            "bad-policy",
            "\"policy\" must be \"log_only\", \"partial\" or \"shed\"",
        )),
    }
}

fn query_result_response(r: &QueryResult) -> Response {
    let status = if r.partial { 206 } else { 200 };
    Response::json(status, query_result_body(r))
}

/// Serialize a [`QueryResult`] losslessly: f32 distances widen exactly to
/// f64, and the writer's shortest-roundtrip float formatting means a
/// client parsing this body reconstructs bit-identical values (the E2E
/// suite pins that against a direct `Orchestrator` call).
fn query_result_body(r: &QueryResult) -> String {
    let mut o = JsonObj::new();
    o.insert("qid", num(r.qid));
    o.insert("prediction", Json::Bool(r.prediction));
    o.insert("positive_share", Json::Num(r.positive_share));
    o.insert("partial", Json::Bool(r.partial));
    o.insert("shed_nodes", num(r.shed_nodes as u64));
    o.insert("max_comparisons", num(r.max_comparisons));
    o.insert("latency_s", Json::Num(r.latency_s));
    o.insert(
        "neighbors",
        Json::Arr(
            r.neighbors
                .iter()
                .map(|n| {
                    let mut m = JsonObj::new();
                    m.insert("id", num(n.id));
                    m.insert("dist", Json::Num(f64::from(n.dist)));
                    m.insert("label", Json::Bool(n.label));
                    Json::Obj(m)
                })
                .collect(),
        ),
    );
    o.insert(
        "per_node_comparisons",
        Json::Arr(
            r.per_node_comparisons
                .iter()
                .map(|pc| Json::Arr(pc.iter().map(|&c| num(c)).collect()))
                .collect(),
        ),
    );
    Json::Obj(o).to_string_compact()
}

fn edge_json(s: &EdgeStats) -> Json {
    let mut o = JsonObj::new();
    for (name, e) in [
        ("query", s.query),
        ("insert", s.insert),
        ("stats", s.stats),
        ("health", s.health),
        ("metrics", s.metrics),
        ("other", s.other),
    ] {
        let mut row = JsonObj::new();
        row.insert("requests", num(e.requests));
        row.insert("errors", num(e.errors));
        row.insert("latency_us_sum", num(e.latency_us_sum));
        // Distribution summary from the per-endpoint histogram: the mean
        // alone hides tails, which is the whole reason the histogram
        // exists. Percentiles report each bucket's inclusive upper bound.
        row.insert("latency_us_mean", Json::Num(e.latency_us.mean()));
        row.insert("latency_us_p50", num(e.latency_us.p50()));
        row.insert("latency_us_p99", num(e.latency_us.p99()));
        o.insert(name, Json::Obj(row));
    }
    Json::Obj(o)
}

fn lane_json(l: &LaneStats) -> Json {
    let mut o = JsonObj::new();
    o.insert("depth", num(l.depth as u64));
    o.insert("high_water", num(l.high_water as u64));
    o.insert("submitted", num(l.submitted));
    o.insert("dispatched_fill", num(l.dispatched_fill));
    o.insert("dispatched_deadline", num(l.dispatched_deadline));
    o.insert("dispatched_aged", num(l.dispatched_aged));
    o.insert("dispatched_drain", num(l.dispatched_drain));
    o.insert("overruns", num(l.overruns));
    o.insert("partials", num(l.partials));
    o.insert("sheds", num(l.sheds));
    o.insert("inserted", num(l.inserted));
    o.insert("rejected_full", num(l.rejected_full));
    o.insert("probes", num(u64::from(l.probes)));
    o.insert("ewma_comparisons", num(l.ewma_comparisons));
    Json::Obj(o)
}

fn admission_json(s: &AdmissionStats) -> Json {
    let mut o = JsonObj::new();
    o.insert("depth", num(s.depth as u64));
    o.insert("high_water", num(s.high_water as u64));
    o.insert("submitted", num(s.submitted));
    o.insert("completed", num(s.completed));
    o.insert("rejected_full", num(s.rejected_full));
    o.insert("cuts_fill", num(s.cuts_fill));
    o.insert("cuts_deadline", num(s.cuts_deadline));
    o.insert("cuts_aged", num(s.cuts_aged));
    o.insert("cuts_drain", num(s.cuts_drain));
    o.insert("auto_probes", Json::Bool(s.auto_probes));
    o.insert("monitor", lane_json(&s.monitor));
    o.insert("analytics", lane_json(&s.analytics));
    Json::Obj(o)
}

fn ingest_json(s: &IngestStats) -> Json {
    let mut o = JsonObj::new();
    o.insert("batches", num(s.batches));
    o.insert("points", num(s.points));
    o.insert("sealed_segments", num(s.sealed_segments));
    Json::Obj(o)
}

fn failover_json(s: &FailoverStats) -> Json {
    let mut o = JsonObj::new();
    o.insert("hedges", num(s.hedges));
    o.insert("hedge_wins", num(s.hedge_wins));
    o.insert("failovers", num(s.failovers));
    o.insert("synthesized_sheds", num(s.synthesized_sheds));
    o.insert("heartbeats", num(s.heartbeats));
    o.insert("reconnect_attempts", num(s.reconnect_attempts));
    o.insert("reconnects", num(s.reconnects));
    o.insert("down_transitions", num(s.down_transitions));
    o.insert("replicas_down", num(s.replicas_down));
    Json::Obj(o)
}
