//! TCP node server + remote-node client.
//!
//! `dslsh serve-node --listen <addr>` runs [`serve_node`]: it waits for
//! the Orchestrator's `Build`, spawns a [`LocalNode`] thread group over
//! the received shard, then serves `Query` frames until `Shutdown`/EOF.
//!
//! [`RemoteNode`] is the Orchestrator-side counterpart: it ships the shard
//! and hash spec over the socket and then satisfies the
//! [`NodeHandle`](crate::coordinator::NodeHandle) contract with one
//! request/response round trip per query — the paper's low-QPS ICU
//! latency model needs no pipelining.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::admission::{Budget, Class};
use crate::coordinator::orchestrator::NodeHandle;
use crate::engine::native::NativeEngine;
use crate::engine::DistanceEngine;
use crate::node::node::{InsertReply, LocalNode, NodeInfo, NodeReply};
use crate::net::wire::{validate_batch_geometry, BatchReplyItem, Message};
use crate::slsh::{SealPolicy, SlshParams};
use crate::util::clock::SystemClock;

/// Engine factory for served nodes (native by default; the XLA service
/// cannot cross processes, each node process may start its own).
pub type EngineFactory = dyn Fn(usize) -> Vec<Box<dyn DistanceEngine>> + Send;

fn native_factory(p: usize) -> Vec<Box<dyn DistanceEngine>> {
    (0..p).map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>).collect()
}

/// Ship a node's batch answers back as one `ReplyBatch` frame.
fn reply_batch<W: std::io::Write>(
    writer: &mut W,
    qid0: u64,
    replies: Vec<NodeReply>,
) -> Result<()> {
    let items: Vec<BatchReplyItem> = replies
        .into_iter()
        .map(|r| BatchReplyItem {
            neighbors: r.neighbors,
            comparisons: r.comparisons,
            inner_probes: r.inner_probes,
            partial: r.partial,
            shed: r.shed,
        })
        .collect();
    Message::ReplyBatch { qid0, replies: items }.write_frame(writer)?;
    Ok(())
}

/// Serve exactly one Orchestrator connection on `listener`, blocking until
/// the peer shuts down. Returns the number of queries served.
pub fn serve_node(listener: &TcpListener, engines: Option<&EngineFactory>) -> Result<u64> {
    let (stream, peer) = listener.accept().context("accept")?;
    crate::log_info!("node-server", "orchestrator connected from {peer}");
    serve_connection(stream, engines)
}

/// Protocol loop over an accepted stream.
pub fn serve_connection(stream: TcpStream, engines: Option<&EngineFactory>) -> Result<u64> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = BufWriter::new(stream);

    // Phase 1: Build (batch over a shipped shard) or BuildLive (empty
    // streaming node).
    let build = Message::read_frame(&mut reader)
        .map_err(|e| anyhow!("reading build frame: {e}"))?
        .ok_or_else(|| anyhow!("peer closed before Build"))?;
    let (mut node, dim, shard_len) = match build {
        Message::Build { node_id, id_base, p, params, shard } => {
            let shard = Arc::new(shard);
            let engine_vec = match engines {
                Some(f) => f(p as usize),
                None => native_factory(p as usize),
            };
            let dim = shard.dim;
            let node = LocalNode::spawn(
                node_id as usize,
                Arc::clone(&shard),
                id_base,
                &params,
                p as usize,
                engine_vec,
            );
            (node, dim, shard.len() as u64)
        }
        Message::BuildLive { node_id, id_base, p, params, seal_points, seal_age_ns } => {
            let engine_vec = match engines {
                Some(f) => f(p as usize),
                None => native_factory(p as usize),
            };
            let policy = SealPolicy { max_points: seal_points as usize, max_age_ns: seal_age_ns };
            let dim = params.outer.dim;
            let node = LocalNode::spawn_live(
                node_id as usize,
                id_base,
                &params,
                p as usize,
                engine_vec,
                Arc::new(SystemClock::new()),
                policy,
            );
            (node, dim, 0)
        }
        other => bail!("expected Build or BuildLive, got {other:?}"),
    };
    Message::BuildDone {
        node_id: node.node_id() as u32,
        shard_len,
        build_ms: node.info().build_ms,
    }
    .write_frame(&mut writer)?;

    // Phase 2: queries and (live) inserts, freely interleaved.
    let mut served = 0u64;
    loop {
        match Message::read_frame(&mut reader).map_err(|e| anyhow!("reading frame: {e}"))? {
            None | Some(Message::Shutdown) => break,
            Some(Message::Query { qid, q }) => {
                // Same hostile-input hardening as the batch arm: a
                // wrong-dimension query would panic a worker mid-hash.
                if q.len() != dim {
                    bail!("bad query geometry: {} floats for dim {dim}", q.len());
                }
                let reply = node.query(&q);
                Message::Reply {
                    qid,
                    neighbors: reply.neighbors,
                    comparisons: reply.comparisons,
                    inner_probes: reply.inner_probes,
                }
                .write_frame(&mut writer)?;
                served += 1;
            }
            Some(Message::QueryBatch { qid0, nq, qs }) => {
                // `nq` is peer-controlled: reject on mismatch/overflow
                // instead of wrapping (hostile-input hardening shared
                // with the budget arm below).
                let nq = validate_batch_geometry(nq, qs.len(), dim)
                    .map_err(|e| anyhow!("{e}"))?;
                let replies = node.query_batch(Arc::new(qs), nq);
                reply_batch(&mut writer, qid0, replies)?;
                served += nq as u64;
            }
            Some(Message::QueryBatchBudget { qid0, nq, budget_us, class, policy, qs }) => {
                let nq = validate_batch_geometry(nq, qs.len(), dim)
                    .map_err(|e| anyhow!("{e}"))?;
                // Budget enforcement (overrun accounting, early-exit
                // partial scans, shedding) lives inside
                // `LocalNode::query_batch_budget`, shared with the
                // in-process path — so local and remote nodes enforce the
                // shipped remaining budget identically, anchored at
                // their own batch arrival.
                let budget = Budget::enforced(budget_us, policy);
                let replies = node.query_batch_budget(Arc::new(qs), nq, budget, class);
                reply_batch(&mut writer, qid0, replies)?;
                served += nq as u64;
            }
            Some(Message::InsertBatch { seq, n, points, labels }) => {
                if !node.is_live() {
                    bail!("InsertBatch sent to a batch-built node");
                }
                // Same hostile-input hardening as the query-batch arms:
                // the label count was already checked against `n` at
                // decode; the float count is checked against `n × dim`
                // here, where the node's dim is known.
                let n = validate_batch_geometry(n, points.len(), dim)
                    .map_err(|e| anyhow!("{e}"))?;
                debug_assert_eq!(labels.len(), n);
                let r = node.insert_batch(&points, &labels);
                Message::InsertAck {
                    seq,
                    accepted: r.accepted,
                    total: r.total,
                    sealed_now: r.sealed_now,
                    sealed_total: r.sealed_total,
                }
                .write_frame(&mut writer)?;
            }
            Some(other) => bail!("unexpected message {other:?}"),
        }
    }
    crate::log_info!("node-server", "served {served} queries, shutting down");
    Ok(served)
}

/// Orchestrator-side handle to a TCP node.
pub struct RemoteNode {
    node_id: usize,
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    info: NodeInfo,
    next_qid: u64,
    next_insert_seq: u64,
}

impl RemoteNode {
    /// Connect, ship the shard + hash spec, wait for BuildDone.
    pub fn connect<A: ToSocketAddrs>(
        addr: A,
        node_id: usize,
        shard: crate::data::Dataset,
        id_base: u64,
        params: &SlshParams,
        p: usize,
    ) -> Result<RemoteNode> {
        let shard_len = shard.len();
        let build = Message::Build {
            node_id: node_id as u32,
            id_base,
            p: p as u32,
            params: params.clone(),
            shard,
        };
        RemoteNode::connect_inner(addr, node_id, p, shard_len, build)
    }

    /// Connect and spawn an EMPTY live node on the far side: ships a
    /// `BuildLive` frame (params + seal policy, no shard), waits for
    /// BuildDone. The returned handle accepts
    /// [`insert_batch`](NodeHandle::insert_batch) with acks crossing the
    /// wire. Seal capacities above
    /// [`MAX_SEAL_POINTS`](crate::net::wire::MAX_SEAL_POINTS) are
    /// rejected here (the server would refuse the frame as hostile —
    /// extent allocation is proportional to the capacity); local
    /// clusters have no such cap.
    pub fn connect_live<A: ToSocketAddrs>(
        addr: A,
        node_id: usize,
        id_base: u64,
        params: &SlshParams,
        p: usize,
        policy: SealPolicy,
    ) -> Result<RemoteNode> {
        if policy.max_points as u64 > crate::net::wire::MAX_SEAL_POINTS {
            bail!(
                "seal capacity {} exceeds the wire cap {} (remote nodes allocate per extent)",
                policy.max_points,
                crate::net::wire::MAX_SEAL_POINTS
            );
        }
        let build = Message::BuildLive {
            node_id: node_id as u32,
            id_base,
            p: p as u32,
            params: params.clone(),
            seal_points: policy.max_points as u64,
            seal_age_ns: policy.max_age_ns,
        };
        RemoteNode::connect_inner(addr, node_id, p, 0, build)
    }

    fn connect_inner<A: ToSocketAddrs>(
        addr: A,
        node_id: usize,
        p: usize,
        shard_len: usize,
        build: Message,
    ) -> Result<RemoteNode> {
        let stream = TcpStream::connect(addr).context("connecting to node")?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        build.write_frame(&mut writer)?;
        let done = Message::read_frame(&mut reader)
            .map_err(|e| anyhow!("reading BuildDone: {e}"))?
            .ok_or_else(|| anyhow!("node closed during build"))?;
        let Message::BuildDone { build_ms, .. } = done else {
            bail!("expected BuildDone, got {done:?}");
        };
        let info = NodeInfo { node_id, shard_len, cores: p, build_ms };
        Ok(RemoteNode { node_id, reader, writer, info, next_qid: 0, next_insert_seq: 0 })
    }
}

impl NodeHandle for RemoteNode {
    fn node_id(&self) -> usize {
        self.node_id
    }

    fn info(&self) -> NodeInfo {
        self.info.clone()
    }

    fn query(&mut self, q: &[f32]) -> NodeReply {
        let qid = self.next_qid;
        self.next_qid += 1;
        Message::Query { qid, q: q.to_vec() }
            .write_frame(&mut self.writer)
            .expect("remote node write failed");
        let reply = Message::read_frame(&mut self.reader)
            .expect("remote node read failed")
            .expect("remote node closed mid-query");
        let Message::Reply { qid: rqid, neighbors, comparisons, inner_probes } = reply else {
            panic!("expected Reply, got {reply:?}");
        };
        assert_eq!(rqid, qid, "out-of-order reply");
        NodeReply { qid, neighbors, comparisons, inner_probes, partial: false, shed: false }
    }

    /// One frame per batch instead of one round trip per query — the
    /// remote node resolves the block on its batched core path. (The
    /// wire message needs an owned buffer, so this copies once.)
    fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Vec<NodeReply> {
        self.batch_roundtrip(qs, nq, Budget::none(), Class::Analytics)
    }

    /// Admission cuts ship their remaining budget, enforcement policy and
    /// class with the frame (`QueryBatchBudget`) so the remote node
    /// enforces the same cut — anchored at frame arrival, the remaining
    /// value having been computed once at dispatch — and attributes
    /// overruns per lane; caller-formed blocks ([`Budget::none`]) stay on
    /// the plain `QueryBatch` frame for protocol compatibility.
    fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Vec<NodeReply> {
        self.batch_roundtrip(qs, nq, budget, class)
    }

    /// One `InsertBatch` frame per append; the remote live node appends
    /// to its store, fans the insert to its cores, and acks once every
    /// core has indexed the points — so a query batched after this
    /// returns (on this same strictly request/response connection) sees
    /// them, exactly like the in-process path.
    fn insert_batch(&mut self, points: &[f32], labels: &[bool]) -> InsertReply {
        let seq = self.next_insert_seq;
        self.next_insert_seq += 1;
        Message::InsertBatch {
            seq,
            n: labels.len() as u64,
            points: points.to_vec(),
            labels: labels.to_vec(),
        }
        .write_frame(&mut self.writer)
        .expect("remote node write failed");
        let reply = Message::read_frame(&mut self.reader)
            .expect("remote node read failed")
            .expect("remote node closed mid-insert");
        let Message::InsertAck { seq: rseq, accepted, total, sealed_now, sealed_total } = reply
        else {
            panic!("expected InsertAck, got {reply:?}");
        };
        assert_eq!(rseq, seq, "out-of-order insert ack");
        InsertReply { accepted, total, sealed_now, sealed_total }
    }
}

impl RemoteNode {
    fn batch_roundtrip(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Vec<NodeReply> {
        if nq == 0 {
            return Vec::new();
        }
        debug_assert_eq!(qs.len() % nq, 0);
        let qid0 = self.next_qid;
        self.next_qid += nq as u64;
        let frame = if budget.is_none() {
            Message::QueryBatch { qid0, nq: nq as u64, qs: qs.as_ref().clone() }
        } else {
            Message::QueryBatchBudget {
                qid0,
                nq: nq as u64,
                budget_us: budget.remaining_us,
                class,
                policy: budget.policy,
                qs: qs.as_ref().clone(),
            }
        };
        frame.write_frame(&mut self.writer).expect("remote node write failed");
        let reply = Message::read_frame(&mut self.reader)
            .expect("remote node read failed")
            .expect("remote node closed mid-batch");
        let Message::ReplyBatch { qid0: rqid0, replies } = reply else {
            panic!("expected ReplyBatch, got {reply:?}");
        };
        assert_eq!(rqid0, qid0, "out-of-order batch reply");
        assert_eq!(replies.len(), nq, "batch reply arity mismatch");
        replies
            .into_iter()
            .enumerate()
            .map(|(i, item)| NodeReply {
                qid: qid0 + i as u64,
                neighbors: item.neighbors,
                comparisons: item.comparisons,
                inner_probes: item.inner_probes,
                partial: item.partial,
                shed: item.shed,
            })
            .collect()
    }
}

impl Drop for RemoteNode {
    fn drop(&mut self) {
        let _ = Message::Shutdown.write_frame(&mut self.writer);
    }
}
