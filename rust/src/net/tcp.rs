//! TCP node server + remote-node client.
//!
//! `dslsh serve-node --listen <addr>` runs [`serve_node`]: it waits for
//! the Orchestrator's `Build`, spawns a [`LocalNode`] thread group over
//! the received shard, then serves `Query` frames until `Shutdown`/EOF.
//! [`serve_node_loop`] re-accepts after a disconnect, so an orchestrator
//! that lost the connection can re-dial and replay the build.
//!
//! [`RemoteNode`] is the Orchestrator-side counterpart: it ships the shard
//! and hash spec over the socket and then satisfies the
//! [`NodeHandle`](crate::coordinator::NodeHandle) contract with one
//! request/response round trip per query — the paper's low-QPS ICU
//! latency model needs no pipelining.
//!
//! # Failure semantics
//!
//! Transport faults never panic. Every request returns
//! `Result<_, NodeError>`; a write error, read error, mid-frame EOF or
//! protocol desync (wrong frame type, wrong qid) poisons the connection —
//! the handle drops its stream and every later request fails fast with
//! "connection is down" until [`NodeHandle::reconnect`] succeeds. The
//! shard dispatcher owns the retry schedule (capped exponential backoff);
//! this layer only makes faults visible and reconnection possible: the
//! build frame is retained verbatim, so a reconnect re-dials, replays it,
//! and awaits a fresh `BuildDone` — a bit-identical rebuild for batch
//! shards (same seed + shard), an EMPTY index for live nodes (replayed
//! ingest is the replicated orchestrator's job, not the transport's).

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::admission::{Budget, Class};
use crate::coordinator::orchestrator::{NodeError, NodeHandle};
use crate::lsh::probe::ProbeSpec;
use crate::engine::native::NativeEngine;
use crate::engine::DistanceEngine;
use crate::node::node::{HeartbeatReply, InsertReply, LocalNode, NodeInfo, NodeReply};
use crate::net::wire::{validate_batch_geometry, BatchReplyItem, Message};
use crate::runtime::service::note_decode_reject;
use crate::slsh::{SealPolicy, SlshParams};
use crate::util::clock::SystemClock;

/// Engine factory for served nodes (native by default; the XLA service
/// cannot cross processes, each node process may start its own).
pub type EngineFactory = dyn Fn(usize) -> Vec<Box<dyn DistanceEngine>> + Send;

fn native_factory(p: usize) -> Vec<Box<dyn DistanceEngine>> {
    (0..p).map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>).collect()
}

/// Ship a node's batch answers back as one `ReplyBatch` frame, echoing
/// the request's trace id so the orchestrator can attribute the per-node
/// scan spans (`scan_ns`, `tables`) that ride each item.
fn reply_batch<W: std::io::Write>(
    writer: &mut W,
    qid0: u64,
    trace: u64,
    replies: Vec<NodeReply>,
) -> Result<()> {
    let items: Vec<BatchReplyItem> = replies
        .into_iter()
        .map(|r| BatchReplyItem {
            neighbors: r.neighbors,
            comparisons: r.comparisons,
            inner_probes: r.inner_probes,
            scan_ns: r.scan_ns,
            tables: r.tables,
            partial: r.partial,
            shed: r.shed,
        })
        .collect();
    Message::ReplyBatch { qid0, trace, replies: items }.write_frame(writer)?;
    Ok(())
}

/// Serve exactly one Orchestrator connection on `listener`, blocking until
/// the peer shuts down. Returns the number of queries served.
pub fn serve_node(listener: &TcpListener, engines: Option<&EngineFactory>) -> Result<u64> {
    let (stream, peer) = listener.accept().context("accept")?;
    crate::log_info!("node-server", "orchestrator connected from {peer}");
    serve_connection(stream, engines)
}

/// Serve up to `conns` sequential Orchestrator connections, re-accepting
/// after each disconnect — the server half of the reconnect story: a
/// re-dialing [`RemoteNode::reconnect`] replays its build frame and gets
/// a freshly built node. A connection that dies mid-frame is logged, not
/// fatal (the next accept proceeds). Returns total queries served.
pub fn serve_node_loop(
    listener: &TcpListener,
    engines: Option<&EngineFactory>,
    conns: usize,
) -> Result<u64> {
    let mut total = 0u64;
    for i in 0..conns {
        match serve_node(listener, engines) {
            Ok(n) => total += n,
            Err(e) => crate::log_info!("node-server", "connection {i} ended with error: {e}"),
        }
    }
    Ok(total)
}

/// Protocol loop over an accepted stream.
pub fn serve_connection(stream: TcpStream, engines: Option<&EngineFactory>) -> Result<u64> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = BufWriter::new(stream);

    // Phase 1: Build (batch over a shipped shard) or BuildLive (empty
    // streaming node).
    let build = Message::read_frame(&mut reader)
        .map_err(|e| {
            // A frame that fails to decode is otherwise silently dropped
            // with the connection — attribute it by cause so the scrape
            // surface (`dslsh_decode_rejects_total`) makes it visible.
            note_decode_reject(e.kind());
            anyhow!("reading build frame: {e}")
        })?
        .ok_or_else(|| anyhow!("peer closed before Build"))?;
    let (mut node, dim, shard_len) = match build {
        Message::Build { node_id, id_base, p, params, shard } => {
            let shard = Arc::new(shard);
            let engine_vec = match engines {
                Some(f) => f(p as usize),
                None => native_factory(p as usize),
            };
            let dim = shard.dim;
            let node = LocalNode::spawn(
                node_id as usize,
                Arc::clone(&shard),
                id_base,
                &params,
                p as usize,
                engine_vec,
            );
            (node, dim, shard.len() as u64)
        }
        Message::BuildLive { node_id, id_base, p, params, seal_points, seal_age_ns } => {
            let engine_vec = match engines {
                Some(f) => f(p as usize),
                None => native_factory(p as usize),
            };
            let policy = SealPolicy { max_points: seal_points as usize, max_age_ns: seal_age_ns };
            let dim = params.outer.dim;
            let node = LocalNode::spawn_live(
                node_id as usize,
                id_base,
                &params,
                p as usize,
                engine_vec,
                Arc::new(SystemClock::new()),
                policy,
            );
            (node, dim, 0)
        }
        other => bail!("expected Build or BuildLive, got {other:?}"),
    };
    Message::BuildDone {
        node_id: node.node_id() as u32,
        shard_len,
        build_ms: node.info().build_ms,
    }
    .write_frame(&mut writer)?;

    // Phase 2: queries, heartbeats and (live) inserts, freely interleaved.
    let mut served = 0u64;
    loop {
        match Message::read_frame(&mut reader).map_err(|e| {
            note_decode_reject(e.kind());
            anyhow!("reading frame: {e}")
        })? {
            None | Some(Message::Shutdown) => break,
            Some(Message::Query { qid, q }) => {
                // Same hostile-input hardening as the batch arm: a
                // wrong-dimension query would panic a worker mid-hash.
                if q.len() != dim {
                    bail!("bad query geometry: {} floats for dim {dim}", q.len());
                }
                let reply = node.query(&q);
                Message::Reply {
                    qid,
                    neighbors: reply.neighbors,
                    comparisons: reply.comparisons,
                    inner_probes: reply.inner_probes,
                }
                .write_frame(&mut writer)?;
                served += 1;
            }
            Some(Message::QueryBatch { qid0, nq, qs }) => {
                // `nq` is peer-controlled: reject on mismatch/overflow
                // instead of wrapping (hostile-input hardening shared
                // with the budget arm below).
                let nq = validate_batch_geometry(nq, qs.len(), dim)
                    .map_err(|e| anyhow!("{e}"))?;
                let replies = node.query_batch(Arc::new(qs), nq);
                reply_batch(&mut writer, qid0, 0, replies)?;
                served += nq as u64;
            }
            Some(Message::QueryBatchBudget {
                qid0,
                nq,
                budget_us,
                class,
                policy,
                probes,
                max_comparisons,
                trace,
                qs,
            }) => {
                let nq = validate_batch_geometry(nq, qs.len(), dim)
                    .map_err(|e| anyhow!("{e}"))?;
                // Budget enforcement (overrun accounting, early-exit
                // partial scans, shedding) and the probe knobs live
                // inside `LocalNode::query_batch_spec`, shared with the
                // in-process path — so local and remote nodes enforce the
                // shipped remaining budget identically, anchored at
                // their own batch arrival. `probes` was validated into
                // `1..=MAX_PROBES` at decode, so the spec constructor
                // cannot panic on peer input; `budget_us = u64::MAX` is
                // the no-deadline sentinel (budgetless spec riders).
                let budget = Budget::enforced(budget_us, policy);
                let spec = ProbeSpec::new(probes, max_comparisons);
                let replies = node.query_batch_spec(Arc::new(qs), nq, budget, class, spec);
                reply_batch(&mut writer, qid0, trace, replies)?;
                served += nq as u64;
            }
            Some(Message::InsertBatch { seq, n, points, labels }) => {
                if !node.is_live() {
                    bail!("InsertBatch sent to a batch-built node");
                }
                // Same hostile-input hardening as the query-batch arms:
                // the label count was already checked against `n` at
                // decode; the float count is checked against `n × dim`
                // here, where the node's dim is known.
                let n = validate_batch_geometry(n, points.len(), dim)
                    .map_err(|e| anyhow!("{e}"))?;
                debug_assert_eq!(labels.len(), n);
                let r = node.insert_batch(&points, &labels);
                Message::InsertAck {
                    seq,
                    accepted: r.accepted,
                    total: r.total,
                    sealed_now: r.sealed_now,
                    sealed_total: r.sealed_total,
                }
                .write_frame(&mut writer)?;
            }
            Some(Message::Heartbeat { seq }) => {
                // Liveness probe; for live nodes the ack doubles as the
                // cluster-level seal poll (runs the age-seal check a
                // quiet stream would otherwise never hit). Not counted
                // in `served`: heartbeats are the detector's traffic,
                // not the caller's.
                let ack = if node.is_live() {
                    let r = node.poll_seal();
                    Message::HeartbeatAck {
                        seq,
                        live: true,
                        total: r.total,
                        sealed_now: r.sealed_now,
                        sealed_total: r.sealed_total,
                    }
                } else {
                    Message::HeartbeatAck {
                        seq,
                        live: false,
                        total: 0,
                        sealed_now: 0,
                        sealed_total: 0,
                    }
                };
                ack.write_frame(&mut writer)?;
            }
            Some(other) => bail!("unexpected message {other:?}"),
        }
    }
    crate::log_info!("node-server", "served {served} queries, shutting down");
    Ok(served)
}

/// One poisoned-on-error connection (reader/writer over the same stream).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

/// Dial, ship the retained build frame, await `BuildDone`. Shared by the
/// initial connect and every reconnect so both paths build the exact
/// same node on the far side.
fn dial(addrs: &[SocketAddr], build: &Message) -> std::result::Result<(Conn, f64), String> {
    let stream = TcpStream::connect(addrs).map_err(|e| format!("connect: {e}"))?;
    stream.set_nodelay(true).ok();
    let reader =
        BufReader::new(stream.try_clone().map_err(|e| format!("clone stream: {e}"))?);
    let writer = BufWriter::new(stream);
    let mut conn = Conn { reader, writer };
    build.write_frame(&mut conn.writer).map_err(|e| format!("shipping build: {e}"))?;
    match Message::read_frame(&mut conn.reader) {
        Ok(Some(Message::BuildDone { build_ms, .. })) => Ok((conn, build_ms)),
        Ok(Some(other)) => Err(format!("expected BuildDone, got {other:?}")),
        Ok(None) => Err("node closed during build".into()),
        Err(e) => Err(format!("reading BuildDone: {e}")),
    }
}

/// Orchestrator-side handle to a TCP node.
pub struct RemoteNode {
    node_id: usize,
    /// Resolved peer addresses, retained for reconnects.
    addrs: Vec<SocketAddr>,
    /// The build frame, retained verbatim: a reconnect replays it so the
    /// far side rebuilds the identical node (same seed, same shard).
    build: Message,
    /// `None` after a transport fault — every request fails fast until
    /// [`NodeHandle::reconnect`] restores it.
    conn: Option<Conn>,
    info: NodeInfo,
    next_qid: u64,
    next_insert_seq: u64,
    next_hb_seq: u64,
}

impl RemoteNode {
    /// Connect, ship the shard + hash spec, wait for BuildDone.
    pub fn connect<A: ToSocketAddrs>(
        addr: A,
        node_id: usize,
        shard: crate::data::Dataset,
        id_base: u64,
        params: &SlshParams,
        p: usize,
    ) -> Result<RemoteNode> {
        let shard_len = shard.len();
        let build = Message::Build {
            node_id: node_id as u32,
            id_base,
            p: p as u32,
            params: params.clone(),
            shard,
        };
        RemoteNode::connect_inner(addr, node_id, p, shard_len, build)
    }

    /// Connect and spawn an EMPTY live node on the far side: ships a
    /// `BuildLive` frame (params + seal policy, no shard), waits for
    /// BuildDone. The returned handle accepts
    /// [`insert_batch`](NodeHandle::insert_batch) with acks crossing the
    /// wire. Seal capacities above
    /// [`MAX_SEAL_POINTS`](crate::net::wire::MAX_SEAL_POINTS) are
    /// rejected here (the server would refuse the frame as hostile —
    /// extent allocation is proportional to the capacity); local
    /// clusters have no such cap.
    pub fn connect_live<A: ToSocketAddrs>(
        addr: A,
        node_id: usize,
        id_base: u64,
        params: &SlshParams,
        p: usize,
        policy: SealPolicy,
    ) -> Result<RemoteNode> {
        if policy.max_points as u64 > crate::net::wire::MAX_SEAL_POINTS {
            bail!(
                "seal capacity {} exceeds the wire cap {} (remote nodes allocate per extent)",
                policy.max_points,
                crate::net::wire::MAX_SEAL_POINTS
            );
        }
        let build = Message::BuildLive {
            node_id: node_id as u32,
            id_base,
            p: p as u32,
            params: params.clone(),
            seal_points: policy.max_points as u64,
            seal_age_ns: policy.max_age_ns,
        };
        RemoteNode::connect_inner(addr, node_id, p, 0, build)
    }

    fn connect_inner<A: ToSocketAddrs>(
        addr: A,
        node_id: usize,
        p: usize,
        shard_len: usize,
        build: Message,
    ) -> Result<RemoteNode> {
        let addrs: Vec<SocketAddr> =
            addr.to_socket_addrs().context("resolving node address")?.collect();
        if addrs.is_empty() {
            bail!("node address resolved to nothing");
        }
        let (conn, build_ms) = dial(&addrs, &build).map_err(|e| anyhow!("node {node_id}: {e}"))?;
        let info = NodeInfo { node_id, shard_len, cores: p, build_ms };
        Ok(RemoteNode {
            node_id,
            addrs,
            build,
            conn: Some(conn),
            info,
            next_qid: 0,
            next_insert_seq: 0,
            next_hb_seq: 0,
        })
    }

    fn fault(&mut self, detail: String) -> NodeError {
        // Poison the stream: after a fault the frame boundary is gone, so
        // every later request on this connection would read garbage.
        self.conn = None;
        NodeError::new(self.node_id, detail)
    }

    /// One strict request/response round trip; any transport fault
    /// poisons the connection.
    fn exchange(&mut self, frame: &Message) -> std::result::Result<Message, NodeError> {
        let Some(conn) = self.conn.as_mut() else {
            return Err(NodeError::new(self.node_id, "connection is down (awaiting reconnect)"));
        };
        if let Err(e) = frame.write_frame(&mut conn.writer) {
            return Err(self.fault(format!("write failed: {e}")));
        }
        match Message::read_frame(&mut conn.reader) {
            Ok(Some(m)) => Ok(m),
            Ok(None) => Err(self.fault("peer closed mid-request".into())),
            Err(e) => Err(self.fault(format!("read failed: {e}"))),
        }
    }

    fn batch_roundtrip(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
        probe: ProbeSpec,
        trace: u64,
    ) -> std::result::Result<Vec<NodeReply>, NodeError> {
        if nq == 0 {
            return Ok(Vec::new());
        }
        debug_assert_eq!(qs.len() % nq, 0);
        let qid0 = self.next_qid;
        self.next_qid += nq as u64;
        // Baseline-knob budgetless batches stay on the plain `QueryBatch`
        // frame — byte-identical wire traffic to a pre-spec client.
        // Anything carrying a knob (a budget, extra probes, a cap, or a
        // trace id — the plain frame has no trace field) rides
        // `QueryBatchBudget`, with `u64::MAX` as the no-deadline budget
        // when only probe knobs are set.
        let frame = if budget.is_none() && probe.is_baseline() && trace == 0 {
            Message::QueryBatch { qid0, nq: nq as u64, qs: qs.as_ref().clone() }
        } else {
            Message::QueryBatchBudget {
                qid0,
                nq: nq as u64,
                budget_us: budget.remaining_us,
                class,
                policy: budget.policy,
                probes: probe.probes,
                max_comparisons: probe.max_comparisons,
                trace,
                qs: qs.as_ref().clone(),
            }
        };
        let reply = self.exchange(&frame)?;
        let Message::ReplyBatch { qid0: rqid0, trace: rtrace, replies } = reply else {
            return Err(self.fault(format!("expected ReplyBatch, got {reply:?}")));
        };
        if rqid0 != qid0 {
            return Err(self.fault(format!("out-of-order batch reply: {rqid0} != {qid0}")));
        }
        // The plain `QueryBatch` frame carries no trace, so its replies
        // legitimately echo 0; a budget-frame reply must echo the request's
        // id exactly — a mismatch means the peer crossed two requests.
        let expected_trace = if matches!(frame, Message::QueryBatch { .. }) { 0 } else { trace };
        if rtrace != expected_trace {
            return Err(self.fault(format!("trace mismatch: {rtrace} != {expected_trace}")));
        }
        if replies.len() != nq {
            return Err(self.fault(format!("batch reply arity {} != {nq}", replies.len())));
        }
        Ok(replies
            .into_iter()
            .enumerate()
            .map(|(i, item)| NodeReply {
                qid: qid0 + i as u64,
                neighbors: item.neighbors,
                comparisons: item.comparisons,
                inner_probes: item.inner_probes,
                scan_ns: item.scan_ns,
                tables: item.tables,
                partial: item.partial,
                shed: item.shed,
            })
            .collect())
    }
}

impl NodeHandle for RemoteNode {
    fn node_id(&self) -> usize {
        self.node_id
    }

    fn info(&self) -> NodeInfo {
        self.info.clone()
    }

    fn query(&mut self, q: &[f32]) -> std::result::Result<NodeReply, NodeError> {
        let qid = self.next_qid;
        self.next_qid += 1;
        let reply = self.exchange(&Message::Query { qid, q: q.to_vec() })?;
        let Message::Reply { qid: rqid, neighbors, comparisons, inner_probes } = reply else {
            return Err(self.fault(format!("expected Reply, got {reply:?}")));
        };
        if rqid != qid {
            return Err(self.fault(format!("out-of-order reply: {rqid} != {qid}")));
        }
        // The single-query `Reply` frame predates scan spans and carries
        // none — zeros here, the batch path is the observable one.
        Ok(NodeReply {
            qid,
            neighbors,
            comparisons,
            inner_probes,
            scan_ns: 0,
            tables: 0,
            partial: false,
            shed: false,
        })
    }

    /// One frame per batch instead of one round trip per query — the
    /// remote node resolves the block on its batched core path. (The
    /// wire message needs an owned buffer, so this copies once.)
    fn query_batch(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
    ) -> std::result::Result<Vec<NodeReply>, NodeError> {
        self.batch_roundtrip(qs, nq, Budget::none(), Class::Analytics, ProbeSpec::BASELINE, 0)
    }

    /// Admission cuts ship their remaining budget, enforcement policy and
    /// class with the frame (`QueryBatchBudget`) so the remote node
    /// enforces the same cut — anchored at frame arrival, the remaining
    /// value having been computed once at dispatch — and attributes
    /// overruns per lane; caller-formed blocks ([`Budget::none`]) stay on
    /// the plain `QueryBatch` frame for protocol compatibility.
    fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> std::result::Result<Vec<NodeReply>, NodeError> {
        self.batch_roundtrip(qs, nq, budget, class, ProbeSpec::BASELINE, 0)
    }

    /// The spec-carrying batch path: probe knobs travel in the
    /// `QueryBatchBudget` frame (with the `u64::MAX` no-deadline sentinel
    /// when the request is budgetless) so the far node runs the same
    /// multi-probe, candidate-capped scan an in-process node would.
    fn query_batch_spec(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
        probe: ProbeSpec,
    ) -> std::result::Result<Vec<NodeReply>, NodeError> {
        self.batch_roundtrip(qs, nq, budget, class, probe, 0)
    }

    /// Traced batch: the trace id rides the `QueryBatchBudget` frame (a
    /// non-zero id forces the budget frame even for baseline budgetless
    /// requests — the plain frame cannot carry it) and must be echoed in
    /// the reply, which brings back the node's per-query scan spans.
    fn query_batch_traced(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
        probe: ProbeSpec,
        trace: u64,
    ) -> std::result::Result<Vec<NodeReply>, NodeError> {
        self.batch_roundtrip(qs, nq, budget, class, probe, trace)
    }

    /// One `InsertBatch` frame per append; the remote live node appends
    /// to its store, fans the insert to its cores, and acks once every
    /// core has indexed the points — so a query batched after this
    /// returns (on this same strictly request/response connection) sees
    /// them, exactly like the in-process path.
    fn insert_batch(
        &mut self,
        points: &[f32],
        labels: &[bool],
    ) -> std::result::Result<InsertReply, NodeError> {
        let seq = self.next_insert_seq;
        self.next_insert_seq += 1;
        let frame = Message::InsertBatch {
            seq,
            n: labels.len() as u64,
            points: points.to_vec(),
            labels: labels.to_vec(),
        };
        let reply = self.exchange(&frame)?;
        let Message::InsertAck { seq: rseq, accepted, total, sealed_now, sealed_total } = reply
        else {
            return Err(self.fault(format!("expected InsertAck, got {reply:?}")));
        };
        if rseq != seq {
            return Err(self.fault(format!("out-of-order insert ack: {rseq} != {seq}")));
        }
        Ok(InsertReply { accepted, total, sealed_now, sealed_total })
    }

    /// One `Heartbeat` frame; the ack carries the far node's liveness and
    /// ingest counters (the cluster-level seal poll rides this probe).
    fn heartbeat(&mut self) -> std::result::Result<HeartbeatReply, NodeError> {
        let seq = self.next_hb_seq;
        self.next_hb_seq += 1;
        let reply = self.exchange(&Message::Heartbeat { seq })?;
        let Message::HeartbeatAck { seq: rseq, live, total, sealed_now, sealed_total } = reply
        else {
            return Err(self.fault(format!("expected HeartbeatAck, got {reply:?}")));
        };
        if rseq != seq {
            return Err(self.fault(format!("out-of-order heartbeat ack: {rseq} != {seq}")));
        }
        Ok(HeartbeatReply { live, total, sealed_now, sealed_total })
    }

    /// Re-dial and replay the retained build frame, awaiting a fresh
    /// `BuildDone`. Batch shards rebuild bit-identically (same seed, same
    /// shard bytes); a live node comes back EMPTY — re-populating it is
    /// the replicated orchestrator's responsibility, not the transport's.
    /// All request sequence counters reset with the new connection.
    fn reconnect(&mut self) -> std::result::Result<(), NodeError> {
        self.conn = None;
        let (conn, build_ms) =
            dial(&self.addrs, &self.build).map_err(|e| NodeError::new(self.node_id, e))?;
        self.info.build_ms = build_ms;
        self.conn = Some(conn);
        self.next_qid = 0;
        self.next_insert_seq = 0;
        self.next_hb_seq = 0;
        Ok(())
    }
}

impl Drop for RemoteNode {
    fn drop(&mut self) {
        if let Some(conn) = self.conn.as_mut() {
            let _ = Message::Shutdown.write_frame(&mut conn.writer);
        }
    }
}
