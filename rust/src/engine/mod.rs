//! Distance engines — the candidate-scan hot path.
//!
//! The paper identifies the linear search over LSH candidates as the
//! bottleneck for large datasets; DSLSH makes that scan a pluggable
//! [`DistanceEngine`]:
//!
//! * [`native::NativeEngine`] — portable Rust scan with runtime kernel
//!   dispatch ([`ScanKernel`]): explicit 4-lane SIMD (SSE2/NEON) kept
//!   bit-identical to the scalar reference, plus an opt-in 8-lane AVX2
//!   kernel behind the `wide-simd` feature (see the kernel contract in
//!   [`native`]'s module docs);
//! * [`crate::runtime::XlaEngine`] — the AOT path: a JAX/Pallas kernel
//!   lowered to HLO at build time and executed through PJRT, proving the
//!   three-layer composition on the live request path.
//!
//! Every engine counts **comparisons** (distance computations) — the
//! paper's machine-independent speed metric. Kernel dispatch lives under
//! the [`DistanceEngine`] trait surface: the [`ScanCancel`]-aware tiled
//! entry points (`scan_until`, `scan_batch_range_until`) and the default
//! `scan_range`/`scan_batch*` methods all funnel into the overridable
//! `scan`/`scan_batch` core, so a dispatched kernel covers every call
//! site — single, batched, cancellable, live-delta and multi-probe —
//! without the callers knowing which ISA ran.

pub mod native;

pub use native::ScanKernel;

use std::cell::Cell;
use std::sync::Arc;

use crate::knn::heap::{Neighbor, TopK};
use crate::util::clock::Clock;

/// Distance metrics supported by the scan.
pub use crate::lsh::family::Metric;

/// Cooperative deadline token for budget-enforced scans.
///
/// The scan kernels check it at *tile* granularity ([`CANCEL_TILE`] rows
/// or candidates between checks), so the clock is read once per tile of
/// work instead of once per row — amortized to noise against the tile's
/// distance computations. The verdict latches: once the deadline has
/// passed, `blown` answers without touching the clock again, and an
/// unbounded token never reads it at all.
///
/// The token holds an injected [`Clock`], so enforcement tests drive it
/// with `MockClock`/`TickClock` and are deterministic — no sleeps, no
/// machine-speed assumptions. It is intentionally NOT `Sync` (one token
/// belongs to one scanning thread); the engines take it by reference
/// alongside `&self`, which stays `Send + Sync`.
pub struct ScanCancel {
    clock: Arc<dyn Clock>,
    deadline_ns: u64,
    blown: Cell<bool>,
}

impl ScanCancel {
    /// A token that trips once `clock` reaches `deadline_ns` (a blown
    /// deadline in the past trips on the first check).
    pub fn until(clock: Arc<dyn Clock>, deadline_ns: u64) -> ScanCancel {
        ScanCancel { clock, deadline_ns, blown: Cell::new(false) }
    }

    /// A token that never trips (and never reads the clock) — the
    /// enforced code paths degenerate to the unenforced ones with it.
    pub fn unbounded(clock: Arc<dyn Clock>) -> ScanCancel {
        ScanCancel::until(clock, u64::MAX)
    }

    /// Has the deadline passed? Reads the clock at most once per call and
    /// not at all once the verdict is latched (or when unbounded).
    pub fn blown(&self) -> bool {
        if self.blown.get() {
            return true;
        }
        if self.deadline_ns == u64::MAX {
            return false;
        }
        if self.clock.now_ns() >= self.deadline_ns {
            self.blown.set(true);
            true
        } else {
            false
        }
    }
}

/// Outcome of a cancellable range scan: how much work was done and
/// whether the range was finished or the deadline cut it short.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScanProgress {
    /// Distance computations actually performed.
    pub comparisons: u64,
    /// `false` when the deadline stopped the scan before the range end.
    pub completed: bool,
}

/// Scalar reference distances (also the oracle for engine tests).
#[inline]
pub fn l1_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += (x - y).abs();
    }
    acc
}

/// Cosine *distance* = 1 − cos(x, y), in [0, 2]. Zero vectors are defined
/// to be at distance 1 from everything (neutral).
#[inline]
pub fn cosine_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f32, 0.0f32, 0.0f32);
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na == 0.0 || nb == 0.0 {
        return 1.0;
    }
    1.0 - dot / (na.sqrt() * nb.sqrt())
}

/// A batched candidate-scan backend.
///
/// `data` is the node shard (row-major `n × dim`), `ids` are local row
/// indices to score against `q`; survivors are pushed into `topk` with
/// global ids `id_base + id` and their labels. Returns the number of
/// distance computations performed (== `ids.len()`).
pub trait DistanceEngine: Send + Sync {
    fn name(&self) -> &'static str;

    fn scan(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64;

    /// Scan a contiguous row range (the PKNN exhaustive path). The default
    /// implementation walks the range through a small stack id buffer —
    /// no heap allocation per call; engines can specialize further to skip
    /// ids entirely.
    fn scan_range(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        let mut buf = [0u32; RANGE_CHUNK];
        let mut total = 0u64;
        let mut next = range.start;
        while next < range.end {
            let n = ((range.end - next) as usize).min(RANGE_CHUNK);
            for (i, slot) in buf[..n].iter_mut().enumerate() {
                *slot = next + i as u32;
            }
            total += self.scan(metric, q, data, dim, &buf[..n], labels, id_base, topk);
            next += n as u32;
        }
        total
    }

    /// Scan the SAME candidate id list for a block of queries (`qs` is
    /// row-major `topks.len() × dim`; `topks[i]` receives query `i`'s
    /// results). This is the register-blocking entry point: engines that
    /// override it amortize each data-row load across the whole query
    /// block. Results MUST be bit-identical to calling [`scan`] once per
    /// query; the default implementation does exactly that. Returns total
    /// distance computations (`topks.len() * ids.len()`).
    #[allow(clippy::too_many_arguments)]
    fn scan_batch(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) -> u64 {
        debug_assert_eq!(qs.len(), topks.len() * dim);
        let mut total = 0u64;
        for (qi, topk) in topks.iter_mut().enumerate() {
            let q = &qs[qi * dim..(qi + 1) * dim];
            total += self.scan(metric, q, data, dim, ids, labels, id_base, topk);
        }
        total
    }

    /// Range variant of [`scan_batch`] (the batched PKNN path). Same
    /// bit-identity contract against per-query [`scan_range`].
    #[allow(clippy::too_many_arguments)]
    fn scan_batch_range(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) -> u64 {
        debug_assert_eq!(qs.len(), topks.len() * dim);
        let mut total = 0u64;
        for (qi, topk) in topks.iter_mut().enumerate() {
            let q = &qs[qi * dim..(qi + 1) * dim];
            total += self.scan_range(metric, q, data, dim, range.clone(), labels, id_base, topk);
        }
        total
    }

    /// Cancellable candidate scan — the budget-enforcement entry point of
    /// the SLSH serving path. Identical contract to [`scan`], except the
    /// id list is walked in [`CANCEL_TILE`]-sized tiles with a deadline
    /// check between tiles: once `cancel` is blown, the remaining ids are
    /// skipped. Returns the comparisons actually performed (`< ids.len()`
    /// means the scan was cut short). When the deadline never trips, the
    /// result is bit-identical to [`scan`] — the tiles preserve candidate
    /// order, so every top-K push happens in the same sequence.
    ///
    /// [`scan`]: DistanceEngine::scan
    #[allow(clippy::too_many_arguments)]
    fn scan_until(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
        cancel: &ScanCancel,
    ) -> u64 {
        let mut total = 0u64;
        for tile in ids.chunks(CANCEL_TILE) {
            if cancel.blown() {
                break;
            }
            total += self.scan(metric, q, data, dim, tile, labels, id_base, topk);
        }
        total
    }

    /// Cancellable twin of [`scan_batch_range`] (the batched exhaustive /
    /// PKNN path): the row range is walked in [`CANCEL_TILE`]-row tiles
    /// with a deadline check between tiles, so a blown budget stops the
    /// scan within one tile of work instead of finishing the shard.
    /// Row-ascending order is preserved, so the retained top-K equals a
    /// plain [`scan_batch_range`] over the prefix that was actually
    /// scanned — partial results are prefixes, never samples.
    ///
    /// [`scan_batch_range`]: DistanceEngine::scan_batch_range
    #[allow(clippy::too_many_arguments)]
    fn scan_batch_range_until(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
        cancel: &ScanCancel,
    ) -> ScanProgress {
        let mut comparisons = 0u64;
        let mut next = range.start;
        while next < range.end {
            if cancel.blown() {
                return ScanProgress { comparisons, completed: false };
            }
            let end = range.end.min(next + CANCEL_TILE as u32);
            comparisons +=
                self.scan_batch_range(metric, qs, data, dim, next..end, labels, id_base, topks);
            next = end;
        }
        ScanProgress { comparisons, completed: true }
    }
}

/// Stack-buffer chunk size for the default `scan_range` implementation.
const RANGE_CHUNK: usize = 256;

/// Rows/candidates scanned between deadline checks in the cancellable
/// kernels — one clock read per tile of `CANCEL_TILE × dim` floats, so
/// enforcement overhead is amortized to noise.
pub const CANCEL_TILE: usize = 256;

/// Push one scored candidate — shared by engine implementations.
#[inline]
pub fn push_scored(topk: &mut TopK, id_base: u64, id: u32, dist: f32, labels: &[bool]) {
    topk.push(Neighbor { id: id_base + id as u64, dist, label: labels[id as usize] });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeEngine;
    use crate::util::clock::{MockClock, TickClock};
    use crate::util::rng::Xoshiro256;

    fn fixture(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.3)).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        (data, labels, q)
    }

    #[test]
    fn unbounded_cancel_is_bit_identical_to_plain_scan() {
        let (data, labels, q) = fixture(700, 30, 11);
        let engine = NativeEngine::new();
        let ids: Vec<u32> = (0..700).collect();
        let cancel = ScanCancel::unbounded(Arc::new(MockClock::new(0)));
        let mut a = TopK::new(7);
        let mut b = TopK::new(7);
        let na = engine.scan(Metric::L1, &q, &data, 30, &ids, &labels, 0, &mut a);
        let nb = engine.scan_until(Metric::L1, &q, &data, 30, &ids, &labels, 0, &mut b, &cancel);
        assert_eq!(na, nb);
        assert_eq!(a.into_sorted(), b.into_sorted());
        // Range variant: same bit-identity through the tiled walk.
        let qs: Vec<f32> = q.iter().chain(q.iter()).copied().collect();
        let mut c: Vec<TopK> = (0..2).map(|_| TopK::new(7)).collect();
        let mut d: Vec<TopK> = (0..2).map(|_| TopK::new(7)).collect();
        let nc = engine.scan_batch_range(Metric::L1, &qs, &data, 30, 3..691, &labels, 0, &mut c);
        let prog = engine.scan_batch_range_until(
            Metric::L1,
            &qs,
            &data,
            30,
            3..691,
            &labels,
            0,
            &mut d,
            &cancel,
        );
        assert!(prog.completed);
        assert_eq!(prog.comparisons, nc);
        for (x, y) in c.into_iter().zip(d) {
            assert_eq!(x.into_sorted(), y.into_sorted());
        }
    }

    #[test]
    fn already_blown_deadline_does_no_work() {
        let (data, labels, q) = fixture(300, 30, 12);
        let engine = NativeEngine::new();
        let ids: Vec<u32> = (0..300).collect();
        // Deadline at the clock's current instant: blown on the first check.
        let cancel = ScanCancel::until(Arc::new(MockClock::new(5_000)), 5_000);
        let mut topk = TopK::new(5);
        let n = engine.scan_until(Metric::L1, &q, &data, 30, &ids, &labels, 0, &mut topk, &cancel);
        assert_eq!(n, 0);
        assert!(topk.is_empty());
        let mut topks = [TopK::new(5)];
        let prog = engine.scan_batch_range_until(
            Metric::L1,
            &q,
            &data,
            30,
            0..300,
            &labels,
            0,
            &mut topks,
            &cancel,
        );
        assert_eq!(prog, ScanProgress { comparisons: 0, completed: false });
        assert!(topks[0].is_empty());
    }

    #[test]
    fn mid_scan_cancel_yields_exact_tile_prefix() {
        // TickClock: each deadline check costs 1ns, so a deadline of D
        // allows exactly D checks = D tiles before the scan stops — and
        // the retained top-K must equal a plain scan over that prefix.
        let (data, labels, q) = fixture(1000, 30, 13);
        let engine = NativeEngine::new();
        let ids: Vec<u32> = (0..1000).collect();
        for allowed_tiles in [1usize, 2, 3] {
            let cancel =
                ScanCancel::until(Arc::new(TickClock::new(0, 1)), allowed_tiles as u64);
            let mut partial = TopK::new(9);
            let n = engine
                .scan_until(Metric::L1, &q, &data, 30, &ids, &labels, 0, &mut partial, &cancel);
            let want = (allowed_tiles * CANCEL_TILE).min(ids.len());
            assert_eq!(n as usize, want, "tiles={allowed_tiles}");
            let mut prefix = TopK::new(9);
            engine.scan(Metric::L1, &q, &data, 30, &ids[..want], &labels, 0, &mut prefix);
            assert_eq!(partial.into_sorted(), prefix.into_sorted(), "tiles={allowed_tiles}");
        }
        // Range variant: same prefix semantics over row tiles.
        let cancel = ScanCancel::until(Arc::new(TickClock::new(0, 1)), 2);
        let mut topks = [TopK::new(9)];
        let prog = engine.scan_batch_range_until(
            Metric::L1,
            &q,
            &data,
            30,
            0..1000,
            &labels,
            0,
            &mut topks,
            &cancel,
        );
        assert_eq!(prog, ScanProgress { comparisons: 2 * CANCEL_TILE as u64, completed: false });
        let mut prefix = TopK::new(9);
        let end = 2 * CANCEL_TILE as u32;
        engine.scan_range(Metric::L1, &q, &data, 30, 0..end, &labels, 0, &mut prefix);
        assert_eq!(topks[0].clone().into_sorted(), prefix.into_sorted());
    }

    #[test]
    fn cancel_latches_and_unbounded_never_reads_the_clock() {
        // Latching: after the first blown verdict the clock is not read
        // again — with a TickClock the timestamp would keep climbing, so
        // equal reads before/after prove no further reads happened.
        let clock = Arc::new(TickClock::new(0, 1));
        let cancel = ScanCancel::until(Arc::clone(&clock) as Arc<dyn Clock>, 1);
        assert!(!cancel.blown()); // read 0 < 1
        assert!(cancel.blown()); // read 1 >= 1: latch
        let stamp = clock.now_ns();
        assert!(cancel.blown());
        assert!(cancel.blown());
        assert_eq!(clock.now_ns(), stamp + 1, "latched verdict must not read the clock");
        // Unbounded: never reads.
        let clock = Arc::new(TickClock::new(0, 1));
        let cancel = ScanCancel::unbounded(Arc::clone(&clock) as Arc<dyn Clock>);
        for _ in 0..10 {
            assert!(!cancel.blown());
        }
        assert_eq!(clock.now_ns(), 0, "unbounded token must not read the clock");
    }

    #[test]
    fn l1_reference_values() {
        assert_eq!(l1_dist(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(l1_dist(&[0.0, 0.0], &[3.0, -4.0]), 7.0);
    }

    #[test]
    fn cosine_reference_values() {
        let e1 = [1.0f32, 0.0];
        let e2 = [0.0f32, 1.0];
        assert!((cosine_dist(&e1, &e1) - 0.0).abs() < 1e-6);
        assert!((cosine_dist(&e1, &e2) - 1.0).abs() < 1e-6);
        let neg = [-1.0f32, 0.0];
        assert!((cosine_dist(&e1, &neg) - 2.0).abs() < 1e-6);
        assert_eq!(cosine_dist(&[0.0, 0.0], &e1), 1.0);
    }

    #[test]
    fn cosine_scale_invariance() {
        let a = [3.0f32, 1.0, -2.0, 0.5];
        let b = [1.0f32, 4.0, 0.0, -1.0];
        let b_scaled: Vec<f32> = b.iter().map(|x| x * 11.0).collect();
        assert!((cosine_dist(&a, &b) - cosine_dist(&a, &b_scaled)).abs() < 1e-6);
    }
}
