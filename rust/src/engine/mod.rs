//! Distance engines — the candidate-scan hot path.
//!
//! The paper identifies the linear search over LSH candidates as the
//! bottleneck for large datasets; DSLSH makes that scan a pluggable
//! [`DistanceEngine`]:
//!
//! * [`native::NativeEngine`] — portable Rust scan (unrolled, branch-light);
//! * [`crate::runtime::XlaEngine`] — the AOT path: a JAX/Pallas kernel
//!   lowered to HLO at build time and executed through PJRT, proving the
//!   three-layer composition on the live request path.
//!
//! Every engine counts **comparisons** (distance computations) — the
//! paper's machine-independent speed metric.

pub mod native;

use crate::knn::heap::{Neighbor, TopK};

/// Distance metrics supported by the scan.
pub use crate::lsh::family::Metric;

/// Scalar reference distances (also the oracle for engine tests).
#[inline]
pub fn l1_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += (x - y).abs();
    }
    acc
}

/// Cosine *distance* = 1 − cos(x, y), in [0, 2]. Zero vectors are defined
/// to be at distance 1 from everything (neutral).
#[inline]
pub fn cosine_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f32, 0.0f32, 0.0f32);
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na == 0.0 || nb == 0.0 {
        return 1.0;
    }
    1.0 - dot / (na.sqrt() * nb.sqrt())
}

/// A batched candidate-scan backend.
///
/// `data` is the node shard (row-major `n × dim`), `ids` are local row
/// indices to score against `q`; survivors are pushed into `topk` with
/// global ids `id_base + id` and their labels. Returns the number of
/// distance computations performed (== `ids.len()`).
pub trait DistanceEngine: Send + Sync {
    fn name(&self) -> &'static str;

    fn scan(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64;

    /// Scan a contiguous row range (the PKNN exhaustive path). The default
    /// implementation walks the range through a small stack id buffer —
    /// no heap allocation per call; engines can specialize further to skip
    /// ids entirely.
    fn scan_range(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        let mut buf = [0u32; RANGE_CHUNK];
        let mut total = 0u64;
        let mut next = range.start;
        while next < range.end {
            let n = ((range.end - next) as usize).min(RANGE_CHUNK);
            for (i, slot) in buf[..n].iter_mut().enumerate() {
                *slot = next + i as u32;
            }
            total += self.scan(metric, q, data, dim, &buf[..n], labels, id_base, topk);
            next += n as u32;
        }
        total
    }

    /// Scan the SAME candidate id list for a block of queries (`qs` is
    /// row-major `topks.len() × dim`; `topks[i]` receives query `i`'s
    /// results). This is the register-blocking entry point: engines that
    /// override it amortize each data-row load across the whole query
    /// block. Results MUST be bit-identical to calling [`scan`] once per
    /// query; the default implementation does exactly that. Returns total
    /// distance computations (`topks.len() * ids.len()`).
    #[allow(clippy::too_many_arguments)]
    fn scan_batch(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) -> u64 {
        debug_assert_eq!(qs.len(), topks.len() * dim);
        let mut total = 0u64;
        for (qi, topk) in topks.iter_mut().enumerate() {
            let q = &qs[qi * dim..(qi + 1) * dim];
            total += self.scan(metric, q, data, dim, ids, labels, id_base, topk);
        }
        total
    }

    /// Range variant of [`scan_batch`] (the batched PKNN path). Same
    /// bit-identity contract against per-query [`scan_range`].
    #[allow(clippy::too_many_arguments)]
    fn scan_batch_range(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) -> u64 {
        debug_assert_eq!(qs.len(), topks.len() * dim);
        let mut total = 0u64;
        for (qi, topk) in topks.iter_mut().enumerate() {
            let q = &qs[qi * dim..(qi + 1) * dim];
            total += self.scan_range(metric, q, data, dim, range.clone(), labels, id_base, topk);
        }
        total
    }
}

/// Stack-buffer chunk size for the default `scan_range` implementation.
const RANGE_CHUNK: usize = 256;

/// Push one scored candidate — shared by engine implementations.
#[inline]
pub fn push_scored(topk: &mut TopK, id_base: u64, id: u32, dist: f32, labels: &[bool]) {
    topk.push(Neighbor { id: id_base + id as u64, dist, label: labels[id as usize] });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_reference_values() {
        assert_eq!(l1_dist(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(l1_dist(&[0.0, 0.0], &[3.0, -4.0]), 7.0);
    }

    #[test]
    fn cosine_reference_values() {
        let e1 = [1.0f32, 0.0];
        let e2 = [0.0f32, 1.0];
        assert!((cosine_dist(&e1, &e1) - 0.0).abs() < 1e-6);
        assert!((cosine_dist(&e1, &e2) - 1.0).abs() < 1e-6);
        let neg = [-1.0f32, 0.0];
        assert!((cosine_dist(&e1, &neg) - 2.0).abs() < 1e-6);
        assert_eq!(cosine_dist(&[0.0, 0.0], &e1), 1.0);
    }

    #[test]
    fn cosine_scale_invariance() {
        let a = [3.0f32, 1.0, -2.0, 0.5];
        let b = [1.0f32, 4.0, 0.0, -1.0];
        let b_scaled: Vec<f32> = b.iter().map(|x| x * 11.0).collect();
        assert!((cosine_dist(&a, &b) - cosine_dist(&a, &b_scaled)).abs() < 1e-6);
    }
}
