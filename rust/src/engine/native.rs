//! Portable Rust distance engine.
//!
//! The scan is memory-bound (30 f32 per row); the implementation keeps the
//! inner loop branch-light and lets LLVM auto-vectorize the fixed-stride
//! accumulation. A 4-way unrolled accumulator breaks the fp dependence
//! chain, which matters on the d=30/32 rows the paper's datasets use.

use crate::engine::{push_scored, DistanceEngine, Metric};
use crate::knn::heap::TopK;

#[derive(Debug, Default, Clone)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        Self
    }
}

/// 4-accumulator L1 distance.
#[inline]
fn l1_unrolled(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += (a[j] - b[j]).abs();
        s1 += (a[j + 1] - b[j + 1]).abs();
        s2 += (a[j + 2] - b[j + 2]).abs();
        s3 += (a[j + 3] - b[j + 3]).abs();
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..n {
        tail += (a[j] - b[j]).abs();
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Fused dot/norm accumulation for cosine.
#[inline]
fn cosine_unrolled(a: &[f32], b: &[f32], a_norm2: f32) -> f32 {
    let mut dot = 0.0f32;
    let mut nb = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        nb += y * y;
    }
    if a_norm2 == 0.0 || nb == 0.0 {
        return 1.0;
    }
    1.0 - dot / (a_norm2.sqrt() * nb.sqrt())
}

impl DistanceEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn scan(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        match metric {
            Metric::L1 => {
                for &id in ids {
                    let row = &data[id as usize * dim..id as usize * dim + dim];
                    let d = l1_unrolled(q, row);
                    push_scored(topk, id_base, id, d, labels);
                }
            }
            Metric::Cosine => {
                let qn: f32 = q.iter().map(|x| x * x).sum();
                for &id in ids {
                    let row = &data[id as usize * dim..id as usize * dim + dim];
                    let d = cosine_unrolled(q, row, qn);
                    push_scored(topk, id_base, id, d, labels);
                }
            }
        }
        ids.len() as u64
    }

    fn scan_range(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        let count = (range.end - range.start) as u64;
        match metric {
            Metric::L1 => {
                for id in range {
                    let row = &data[id as usize * dim..id as usize * dim + dim];
                    let d = l1_unrolled(q, row);
                    push_scored(topk, id_base, id, d, labels);
                }
            }
            Metric::Cosine => {
                let qn: f32 = q.iter().map(|x| x * x).sum();
                for id in range {
                    let row = &data[id as usize * dim..id as usize * dim + dim];
                    let d = cosine_unrolled(q, row, qn);
                    push_scored(topk, id_base, id, d, labels);
                }
            }
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{cosine_dist, l1_dist};
    use crate::util::rng::Xoshiro256;

    fn fixture(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.3)).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        (data, labels, q)
    }

    #[test]
    fn unrolled_matches_scalar_reference() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for dim in [1usize, 3, 4, 7, 30, 32, 33] {
            let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-5.0, 5.0) as f32).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-5.0, 5.0) as f32).collect();
            assert!((l1_unrolled(&a, &b) - l1_dist(&a, &b)).abs() < 1e-4, "dim={dim}");
            let an: f32 = a.iter().map(|x| x * x).sum();
            assert!(
                (cosine_unrolled(&a, &b, an) - cosine_dist(&a, &b)).abs() < 1e-5,
                "dim={dim}"
            );
        }
    }

    #[test]
    fn scan_returns_count_and_correct_topk() {
        let (data, labels, q) = fixture(200, 30, 2);
        let engine = NativeEngine::new();
        let ids: Vec<u32> = (0..200).step_by(2).map(|i| i as u32).collect();
        let mut topk = TopK::new(5);
        let n = engine.scan(Metric::L1, &q, &data, 30, &ids, &labels, 1000, &mut topk);
        assert_eq!(n, ids.len() as u64);
        // Reference: full sort over the same candidates (same summation
        // order as the engine so ranks are comparable exactly).
        let mut reference: Vec<(f32, u64)> = ids
            .iter()
            .map(|&id| (l1_unrolled(&q, &data[id as usize * 30..id as usize * 30 + 30]), 1000 + id as u64))
            .collect();
        reference.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let got = topk.into_sorted();
        for (i, nb) in got.iter().enumerate() {
            assert_eq!(nb.id, reference[i].1, "rank {i}");
            assert!((nb.dist - reference[i].0).abs() < 1e-4);
        }
        // Labels carried through.
        for nb in &got {
            assert_eq!(nb.label, labels[(nb.id - 1000) as usize]);
        }
    }

    #[test]
    fn scan_range_equals_scan_with_ids() {
        let (data, labels, q) = fixture(128, 30, 3);
        let engine = NativeEngine::new();
        for metric in [Metric::L1, Metric::Cosine] {
            let mut a = TopK::new(7);
            let mut b = TopK::new(7);
            let ids: Vec<u32> = (10..90).collect();
            engine.scan(metric, &q, &data, 30, &ids, &labels, 0, &mut a);
            engine.scan_range(metric, &q, &data, 30, 10..90, &labels, 0, &mut b);
            assert_eq!(a.into_sorted(), b.into_sorted());
        }
    }

    #[test]
    fn empty_ids_is_noop() {
        let (data, labels, q) = fixture(10, 30, 4);
        let engine = NativeEngine::new();
        let mut topk = TopK::new(3);
        let n = engine.scan(Metric::L1, &q, &data, 30, &[], &labels, 0, &mut topk);
        assert_eq!(n, 0);
        assert!(topk.is_empty());
    }
}
