//! Portable Rust distance engine — the kernel contract.
//!
//! The scan is memory-bound (30 f32 per row); every kernel here is a
//! different way of feeding that stream through the same arithmetic. The
//! engine runtime-dispatches between them via [`ScanKernel`], and the
//! contract that makes dispatch safe is **reduction order**: a distance is
//! always accumulated into four lanes (`s0..s3`, element `j` goes to lane
//! `j % 4`), reduced as `(s0 + s1) + (s2 + s3)`, then the `n % 4` scalar
//! tail is added. Any two kernels that implement that order produce
//! bit-identical f32 results, so candidate ranking, top-K contents and
//! comparison counts are invariant under dispatch.
//!
//! Dispatch table (dim × ISA × guarantee):
//!
//! | kernel   | dims    | ISA (via `std::arch`)        | guarantee vs scalar    |
//! |----------|---------|------------------------------|------------------------|
//! | `Scalar` | 30 / 32 | none (const-generic bodies)  | identity (it IS scalar)|
//! | `Scalar` | dynamic | none (4-accumulator unroll)  | identity               |
//! | `Simd4`  | any     | SSE2 (x86_64), NEON (aarch64)| **bit-identical**      |
//! | `Simd4`  | any     | other arches: scalar body    | bit-identical (trivial)|
//! | `Simd8`  | any     | AVX2, `wide-simd` feature    | tolerance only (~1e-6) |
//!
//! * **Scalar** — the reference bodies. d = 30 and d = 32 (the paper's
//!   window widths, plus the padded variant) dispatch to const-generic
//!   twins with compile-time trip counts so LLVM fully unrolls them; the
//!   accumulation order is identical, so the specializations are
//!   bit-identical to the dynamic bodies.
//! * **Simd4** — explicit 4-lane f32 kernels. SIMD lane `i` accumulates
//!   exactly the element stream of scalar accumulator `s_i`, and the
//!   horizontal reduction re-creates `(s0 + s1) + (s2 + s3)` in scalar
//!   f32 adds, so results are bit-identical to `Scalar` — every parity
//!   test in the repo doubles as a SIMD gate. SSE2/NEON are baseline on
//!   their architectures: no feature detection is needed for `Simd4`.
//! * **Simd8** — 8-lane AVX2 behind the opt-in `wide-simd` cargo feature.
//!   Eight accumulators reduce as `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))`
//!   with an `n % 8` tail — a *different* reduction tree, so it is
//!   tolerance-tested, never bit-gated, and never auto-selected by
//!   [`ScanKernel::detect`]; opt in per engine with
//!   [`NativeEngine::with_kernel`].
//!
//! Cosine kernels fuse dot and row-norm accumulation; both follow the
//! same lane order (element `j` → lane `j % 4`), which is also what makes
//! hoisting a row's norm out of the batched query tile ([`Q_TILE`]-wide
//! register blocking) bit-identical to the fused single-query path.

use crate::engine::{push_scored, DistanceEngine, Metric};
use crate::knn::heap::TopK;

/// Queries processed per data-row load in the batched kernels.
pub const Q_TILE: usize = 4;

/// Which scan kernel a [`NativeEngine`] runs (see the module docs for the
/// dim × ISA × guarantee table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanKernel {
    /// Portable scalar bodies (4-accumulator unroll + fixed-dim
    /// specializations). The reference everything else is gated against.
    Scalar,
    /// Explicit 4-lane f32 SIMD (SSE2 on x86_64, NEON on aarch64; the
    /// scalar body elsewhere). Bit-identical to [`Scalar`] by
    /// lane-to-accumulator mapping.
    ///
    /// [`Scalar`]: ScanKernel::Scalar
    Simd4,
    /// 8-lane AVX2 (opt-in `wide-simd` feature; requires runtime AVX2).
    /// Different reduction tree — tolerance-grade, never auto-selected.
    Simd8,
}

impl ScanKernel {
    /// The kernel [`NativeEngine::new`] runs: `Simd4` where the 4-lane
    /// ISA is architectural baseline (x86_64 SSE2, aarch64 NEON), else
    /// `Scalar`. Never `Simd8` — the wide kernel is not bit-identical, so
    /// it must be an explicit opt-in, not a detection result.
    pub fn detect() -> ScanKernel {
        if cfg!(any(target_arch = "x86_64", target_arch = "aarch64")) {
            ScanKernel::Simd4
        } else {
            ScanKernel::Scalar
        }
    }

    /// Can [`ScanKernel::Simd8`] run here? True only when the `wide-simd`
    /// feature is compiled in AND the host reports AVX2 at runtime.
    pub fn simd8_available() -> bool {
        #[cfg(all(feature = "wide-simd", target_arch = "x86_64"))]
        {
            return std::arch::is_x86_feature_detected!("avx2");
        }
        #[allow(unreachable_code)]
        false
    }
}

#[derive(Debug, Clone)]
pub struct NativeEngine {
    kernel: ScanKernel,
}

impl NativeEngine {
    /// Runtime-dispatched engine: [`ScanKernel::detect`] picks the widest
    /// kernel that is still bit-identical to the scalar reference.
    pub fn new() -> Self {
        Self { kernel: ScanKernel::detect() }
    }

    /// An engine pinned to one kernel (ablation benches, parity tests).
    ///
    /// # Panics
    /// If `kernel` is [`ScanKernel::Simd8`] and
    /// [`ScanKernel::simd8_available`] is false — the wide kernel cannot
    /// fall back silently without invalidating what an ablation measures.
    pub fn with_kernel(kernel: ScanKernel) -> Self {
        if kernel == ScanKernel::Simd8 {
            assert!(
                ScanKernel::simd8_available(),
                "simd8 needs the wide-simd feature and runtime AVX2"
            );
        }
        Self { kernel }
    }

    /// The kernel this engine dispatches to.
    pub fn kernel(&self) -> ScanKernel {
        self.kernel
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

/// 4-accumulator L1 distance (dynamic length). Element `j` accumulates
/// into lane `j % 4`; reduction is `(s0 + s1) + (s2 + s3)` + scalar tail
/// — the order every other L1 kernel must reproduce.
#[inline]
fn l1_unrolled(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += (a[j] - b[j]).abs();
        s1 += (a[j + 1] - b[j + 1]).abs();
        s2 += (a[j + 2] - b[j + 2]).abs();
        s3 += (a[j + 3] - b[j + 3]).abs();
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..n {
        tail += (a[j] - b[j]).abs();
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Const-length twin of [`l1_unrolled`] — same accumulation order, so the
/// result is bit-identical; the constant trip count lets LLVM fully
/// unroll + vectorize.
#[inline(always)]
fn l1_fixed<const D: usize>(a: &[f32; D], b: &[f32; D]) -> f32 {
    let chunks = D / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += (a[j] - b[j]).abs();
        s1 += (a[j + 1] - b[j + 1]).abs();
        s2 += (a[j + 2] - b[j + 2]).abs();
        s3 += (a[j + 3] - b[j + 3]).abs();
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..D {
        tail += (a[j] - b[j]).abs();
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Dim-dispatching L1: specialized for the paper's 30-wide windows (and
/// the 32-wide padded layout), dynamic otherwise. Bit-identical across
/// arms by construction.
#[inline(always)]
fn l1_dist_dispatch(a: &[f32], b: &[f32]) -> f32 {
    match a.len() {
        30 => l1_fixed::<30>(a.try_into().unwrap(), b.try_into().unwrap()),
        32 => l1_fixed::<32>(a.try_into().unwrap(), b.try_into().unwrap()),
        _ => l1_unrolled(a, b),
    }
}

/// Final cosine expression shared by every cosine kernel (fused and
/// norm-precomputed): identical text ⇒ identical bits once `dot` and the
/// norms match. Zero vectors are at distance 1 from everything.
#[inline(always)]
fn cosine_finish(dot: f32, a_norm2: f32, b_norm2: f32) -> f32 {
    if a_norm2 == 0.0 || b_norm2 == 0.0 {
        return 1.0;
    }
    1.0 - dot / (a_norm2.sqrt() * b_norm2.sqrt())
}

/// Fused 4-wide dot + row-norm accumulation (dynamic length): element `j`
/// feeds dot lane `j % 4` and norm lane `j % 4`; each quad reduces
/// `(x0 + x1) + (x2 + x3)` + scalar tail. This order defines the SIMD
/// cosine lane mapping.
#[inline]
fn dot_nb_unrolled(a: &[f32], b: &[f32]) -> (f32, f32) {
    let n = a.len();
    let chunks = n / 4;
    let (mut d0, mut d1, mut d2, mut d3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut n0, mut n1, mut n2, mut n3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        d0 += a[j] * b[j];
        n0 += b[j] * b[j];
        d1 += a[j + 1] * b[j + 1];
        n1 += b[j + 1] * b[j + 1];
        d2 += a[j + 2] * b[j + 2];
        n2 += b[j + 2] * b[j + 2];
        d3 += a[j + 3] * b[j + 3];
        n3 += b[j + 3] * b[j + 3];
    }
    let (mut dt, mut nt) = (0.0f32, 0.0f32);
    for j in chunks * 4..n {
        dt += a[j] * b[j];
        nt += b[j] * b[j];
    }
    ((d0 + d1) + (d2 + d3) + dt, (n0 + n1) + (n2 + n3) + nt)
}

/// 4-wide dot product only (dynamic length) — the norm-precomputed cosine
/// path. Same lane order and reduction as [`dot_nb_unrolled`]'s dot.
#[inline]
fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut d0, mut d1, mut d2, mut d3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        d0 += a[j] * b[j];
        d1 += a[j + 1] * b[j + 1];
        d2 += a[j + 2] * b[j + 2];
        d3 += a[j + 3] * b[j + 3];
    }
    let mut dt = 0.0f32;
    for j in chunks * 4..n {
        dt += a[j] * b[j];
    }
    (d0 + d1) + (d2 + d3) + dt
}

/// Fused 4-wide cosine (dynamic length) — [`dot_nb_unrolled`] plus the
/// shared [`cosine_finish`].
#[inline]
fn cosine_unrolled(a: &[f32], b: &[f32], a_norm2: f32) -> f32 {
    let (dot, nb) = dot_nb_unrolled(a, b);
    cosine_finish(dot, a_norm2, nb)
}

/// Const-length twin of [`cosine_unrolled`] — identical accumulation
/// order, bit-identical result.
#[inline(always)]
fn cosine_fixed<const D: usize>(a: &[f32; D], b: &[f32; D], a_norm2: f32) -> f32 {
    let chunks = D / 4;
    let (mut d0, mut d1, mut d2, mut d3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut n0, mut n1, mut n2, mut n3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        d0 += a[j] * b[j];
        n0 += b[j] * b[j];
        d1 += a[j + 1] * b[j + 1];
        n1 += b[j + 1] * b[j + 1];
        d2 += a[j + 2] * b[j + 2];
        n2 += b[j + 2] * b[j + 2];
        d3 += a[j + 3] * b[j + 3];
        n3 += b[j + 3] * b[j + 3];
    }
    let (mut dt, mut nt) = (0.0f32, 0.0f32);
    for j in chunks * 4..D {
        dt += a[j] * b[j];
        nt += b[j] * b[j];
    }
    cosine_finish((d0 + d1) + (d2 + d3) + dt, a_norm2, (n0 + n1) + (n2 + n3) + nt)
}

#[inline(always)]
fn cosine_dist_dispatch(a: &[f32], b: &[f32], a_norm2: f32) -> f32 {
    match a.len() {
        30 => cosine_fixed::<30>(a.try_into().unwrap(), b.try_into().unwrap(), a_norm2),
        32 => cosine_fixed::<32>(a.try_into().unwrap(), b.try_into().unwrap(), a_norm2),
        _ => cosine_unrolled(a, b, a_norm2),
    }
}

/// Squared norm in the exact lane order the fused kernels accumulate
/// their `nb` term (it IS [`dot_unrolled`]`(b, b)`), so hoisting a row's
/// norm out of the query tile is bit-identical.
#[inline(always)]
fn norm2(b: &[f32]) -> f32 {
    dot_unrolled(b, b)
}

/// Cosine with BOTH norms precomputed; the dot product uses the same
/// lane-order accumulation as the fused kernels and the final expression
/// is shared ([`cosine_finish`]), so the result is bit-identical to
/// [`cosine_dist_dispatch`] — while each row's norm is computed once per
/// row load instead of once per (query, row) pair.
#[inline(always)]
fn cosine_pre(a: &[f32], b: &[f32], a_norm2: f32, b_norm2: f32) -> f32 {
    cosine_finish(dot_unrolled(a, b), a_norm2, b_norm2)
}

#[inline(always)]
fn cosine_pre_fixed<const D: usize>(a: &[f32; D], b: &[f32; D], a_norm2: f32, b_norm2: f32) -> f32 {
    let chunks = D / 4;
    let (mut d0, mut d1, mut d2, mut d3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        d0 += a[j] * b[j];
        d1 += a[j + 1] * b[j + 1];
        d2 += a[j + 2] * b[j + 2];
        d3 += a[j + 3] * b[j + 3];
    }
    let mut dt = 0.0f32;
    for j in chunks * 4..D {
        dt += a[j] * b[j];
    }
    cosine_finish((d0 + d1) + (d2 + d3) + dt, a_norm2, b_norm2)
}

#[inline(always)]
fn cosine_pre_dispatch(a: &[f32], b: &[f32], a_norm2: f32, b_norm2: f32) -> f32 {
    match a.len() {
        30 => cosine_pre_fixed::<30>(a.try_into().unwrap(), b.try_into().unwrap(), a_norm2, b_norm2),
        32 => cosine_pre_fixed::<32>(a.try_into().unwrap(), b.try_into().unwrap(), a_norm2, b_norm2),
        _ => cosine_pre(a, b, a_norm2, b_norm2),
    }
}

/// Explicit 4-lane SSE2 kernels. SSE2 is part of the x86_64 baseline, so
/// no runtime detection is needed. Lane `i` of the vector accumulator
/// carries exactly scalar accumulator `s_i` (same elements, same add
/// sequence — IEEE f32 ops are deterministic per lane), and the
/// horizontal reduction re-creates `(s0 + s1) + (s2 + s3)` in scalar
/// adds, so every result is bit-identical to the scalar bodies.
#[cfg(target_arch = "x86_64")]
mod simd4 {
    use std::arch::x86_64::*;

    /// Reduce in the scalar order `(s0 + s1) + (s2 + s3)`.
    #[inline(always)]
    fn reduce(v: __m128) -> f32 {
        let mut s = [0.0f32; 4];
        // SAFETY: SSE2 is baseline on x86_64; the store fills 4 floats.
        unsafe { _mm_storeu_ps(s.as_mut_ptr(), v) };
        (s[0] + s[1]) + (s[2] + s[3])
    }

    pub fn l1(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        // SAFETY: SSE2 is baseline; unaligned loads stay within
        // `chunks * 4 <= n` elements of both slices.
        let quads = unsafe {
            let sign = _mm_set1_ps(-0.0);
            let mut acc = _mm_setzero_ps();
            for i in 0..chunks {
                let va = _mm_loadu_ps(a.as_ptr().add(i * 4));
                let vb = _mm_loadu_ps(b.as_ptr().add(i * 4));
                // |x| = clear the sign bit — exactly f32::abs.
                acc = _mm_add_ps(acc, _mm_andnot_ps(sign, _mm_sub_ps(va, vb)));
            }
            acc
        };
        let mut tail = 0.0f32;
        for j in chunks * 4..n {
            tail += (a[j] - b[j]).abs();
        }
        reduce(quads) + tail
    }

    pub fn dot_nb(a: &[f32], b: &[f32]) -> (f32, f32) {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        // SAFETY: as in `l1`. Separate mul + add (never FMA) matches the
        // scalar two-op rounding exactly.
        let (dq, nq) = unsafe {
            let mut dot = _mm_setzero_ps();
            let mut nb = _mm_setzero_ps();
            for i in 0..chunks {
                let va = _mm_loadu_ps(a.as_ptr().add(i * 4));
                let vb = _mm_loadu_ps(b.as_ptr().add(i * 4));
                dot = _mm_add_ps(dot, _mm_mul_ps(va, vb));
                nb = _mm_add_ps(nb, _mm_mul_ps(vb, vb));
            }
            (dot, nb)
        };
        let (mut dt, mut nt) = (0.0f32, 0.0f32);
        for j in chunks * 4..n {
            dt += a[j] * b[j];
            nt += b[j] * b[j];
        }
        (reduce(dq) + dt, reduce(nq) + nt)
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        // SAFETY: as in `l1`.
        let dq = unsafe {
            let mut acc = _mm_setzero_ps();
            for i in 0..chunks {
                let va = _mm_loadu_ps(a.as_ptr().add(i * 4));
                let vb = _mm_loadu_ps(b.as_ptr().add(i * 4));
                acc = _mm_add_ps(acc, _mm_mul_ps(va, vb));
            }
            acc
        };
        let mut dt = 0.0f32;
        for j in chunks * 4..n {
            dt += a[j] * b[j];
        }
        reduce(dq) + dt
    }

    pub fn norm2(b: &[f32]) -> f32 {
        dot(b, b)
    }
}

/// Explicit 4-lane NEON kernels (aarch64 baseline). Same lane mapping and
/// reduction order as the SSE2 module — see its docs.
#[cfg(target_arch = "aarch64")]
mod simd4 {
    use std::arch::aarch64::*;

    /// Reduce in the scalar order `(s0 + s1) + (s2 + s3)`.
    #[inline(always)]
    fn reduce(v: float32x4_t) -> f32 {
        // SAFETY: NEON is baseline on aarch64.
        let (s0, s1, s2, s3) = unsafe {
            (
                vgetq_lane_f32::<0>(v),
                vgetq_lane_f32::<1>(v),
                vgetq_lane_f32::<2>(v),
                vgetq_lane_f32::<3>(v),
            )
        };
        (s0 + s1) + (s2 + s3)
    }

    pub fn l1(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        // SAFETY: NEON is baseline; loads stay within `chunks * 4 <= n`
        // elements. FABS after FSUB (not FABD) so per-lane rounding and
        // NaN handling match the scalar `(x - y).abs()` bit for bit.
        let quads = unsafe {
            let mut acc = vdupq_n_f32(0.0);
            for i in 0..chunks {
                let va = vld1q_f32(a.as_ptr().add(i * 4));
                let vb = vld1q_f32(b.as_ptr().add(i * 4));
                acc = vaddq_f32(acc, vabsq_f32(vsubq_f32(va, vb)));
            }
            acc
        };
        let mut tail = 0.0f32;
        for j in chunks * 4..n {
            tail += (a[j] - b[j]).abs();
        }
        reduce(quads) + tail
    }

    pub fn dot_nb(a: &[f32], b: &[f32]) -> (f32, f32) {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        // SAFETY: as in `l1`. Separate FMUL + FADD (never FMLA) matches
        // the scalar two-op rounding exactly.
        let (dq, nq) = unsafe {
            let mut dot = vdupq_n_f32(0.0);
            let mut nb = vdupq_n_f32(0.0);
            for i in 0..chunks {
                let va = vld1q_f32(a.as_ptr().add(i * 4));
                let vb = vld1q_f32(b.as_ptr().add(i * 4));
                dot = vaddq_f32(dot, vmulq_f32(va, vb));
                nb = vaddq_f32(nb, vmulq_f32(vb, vb));
            }
            (dot, nb)
        };
        let (mut dt, mut nt) = (0.0f32, 0.0f32);
        for j in chunks * 4..n {
            dt += a[j] * b[j];
            nt += b[j] * b[j];
        }
        (reduce(dq) + dt, reduce(nq) + nt)
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        // SAFETY: as in `l1`.
        let dq = unsafe {
            let mut acc = vdupq_n_f32(0.0);
            for i in 0..chunks {
                let va = vld1q_f32(a.as_ptr().add(i * 4));
                let vb = vld1q_f32(b.as_ptr().add(i * 4));
                acc = vaddq_f32(acc, vmulq_f32(va, vb));
            }
            acc
        };
        let mut dt = 0.0f32;
        for j in chunks * 4..n {
            dt += a[j] * b[j];
        }
        reduce(dq) + dt
    }

    pub fn norm2(b: &[f32]) -> f32 {
        dot(b, b)
    }
}

/// Scalar stand-ins for architectures without a 4-lane `std::arch` path.
/// [`ScanKernel::detect`] returns `Scalar` here, but a pinned `Simd4`
/// engine still honors the bit-identity contract trivially: the "simd4"
/// kernel IS the scalar body.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod simd4 {
    pub fn l1(a: &[f32], b: &[f32]) -> f32 {
        super::l1_unrolled(a, b)
    }

    pub fn dot_nb(a: &[f32], b: &[f32]) -> (f32, f32) {
        super::dot_nb_unrolled(a, b)
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        super::dot_unrolled(a, b)
    }

    pub fn norm2(b: &[f32]) -> f32 {
        super::norm2(b)
    }
}

/// 8-lane AVX2 kernels (opt-in `wide-simd` feature). Reduction order is
/// `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))` + an `n % 8` scalar tail — a
/// different tree than the scalar contract, so these are tolerance-grade.
///
/// SAFETY contract for the whole module: callers reach these only through
/// [`ScanKernel::Simd8`], which [`NativeEngine::with_kernel`] refuses to
/// construct unless `is_x86_feature_detected!("avx2")` held.
#[cfg(all(feature = "wide-simd", target_arch = "x86_64"))]
mod simd8 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    unsafe fn reduce(v: __m256) -> f32 {
        let mut s = [0.0f32; 8];
        _mm256_storeu_ps(s.as_mut_ptr(), v);
        ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))
    }

    #[target_feature(enable = "avx2")]
    unsafe fn l1_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let sign = _mm256_set1_ps(-0.0);
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(i * 8));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i * 8));
            acc = _mm256_add_ps(acc, _mm256_andnot_ps(sign, _mm256_sub_ps(va, vb)));
        }
        let mut tail = 0.0f32;
        for j in chunks * 8..n {
            tail += (a[j] - b[j]).abs();
        }
        reduce(acc) + tail
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_nb_avx2(a: &[f32], b: &[f32]) -> (f32, f32) {
        let n = a.len();
        let chunks = n / 8;
        let mut dot = _mm256_setzero_ps();
        let mut nb = _mm256_setzero_ps();
        for i in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(i * 8));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i * 8));
            dot = _mm256_add_ps(dot, _mm256_mul_ps(va, vb));
            nb = _mm256_add_ps(nb, _mm256_mul_ps(vb, vb));
        }
        let (mut dt, mut nt) = (0.0f32, 0.0f32);
        for j in chunks * 8..n {
            dt += a[j] * b[j];
            nt += b[j] * b[j];
        }
        (reduce(dot) + dt, reduce(nb) + nt)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(i * 8));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut dt = 0.0f32;
        for j in chunks * 8..n {
            dt += a[j] * b[j];
        }
        reduce(acc) + dt
    }

    pub fn l1(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        // SAFETY: module contract — Simd8 implies AVX2 was detected.
        unsafe { l1_avx2(a, b) }
    }

    pub fn dot_nb(a: &[f32], b: &[f32]) -> (f32, f32) {
        debug_assert_eq!(a.len(), b.len());
        // SAFETY: module contract — Simd8 implies AVX2 was detected.
        unsafe { dot_nb_avx2(a, b) }
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        // SAFETY: module contract — Simd8 implies AVX2 was detected.
        unsafe { dot_avx2(a, b) }
    }

    pub fn norm2(b: &[f32]) -> f32 {
        dot(b, b)
    }
}

/// Without the `wide-simd` feature (or off x86_64), `ScanKernel::Simd8`
/// is unconstructible — [`NativeEngine::with_kernel`] panics first — but
/// the dispatch arms still have to compile, so delegate to simd4.
#[cfg(not(all(feature = "wide-simd", target_arch = "x86_64")))]
mod simd8 {
    pub fn l1(a: &[f32], b: &[f32]) -> f32 {
        super::simd4::l1(a, b)
    }

    pub fn dot_nb(a: &[f32], b: &[f32]) -> (f32, f32) {
        super::simd4::dot_nb(a, b)
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        super::simd4::dot(a, b)
    }

    pub fn norm2(b: &[f32]) -> f32 {
        super::simd4::norm2(b)
    }
}

/// Kernel-dispatched L1 row distance.
#[inline(always)]
fn l1_row(k: ScanKernel, a: &[f32], b: &[f32]) -> f32 {
    match k {
        ScanKernel::Scalar => l1_dist_dispatch(a, b),
        ScanKernel::Simd4 => simd4::l1(a, b),
        ScanKernel::Simd8 => simd8::l1(a, b),
    }
}

/// Kernel-dispatched fused cosine (row norm accumulated in-kernel).
#[inline(always)]
fn cosine_row(k: ScanKernel, a: &[f32], b: &[f32], a_norm2: f32) -> f32 {
    match k {
        ScanKernel::Scalar => cosine_dist_dispatch(a, b, a_norm2),
        ScanKernel::Simd4 => {
            let (dot, nb) = simd4::dot_nb(a, b);
            cosine_finish(dot, a_norm2, nb)
        }
        ScanKernel::Simd8 => {
            let (dot, nb) = simd8::dot_nb(a, b);
            cosine_finish(dot, a_norm2, nb)
        }
    }
}

/// Kernel-dispatched cosine with both norms precomputed (batched tiles).
#[inline(always)]
fn cosine_pre_row(k: ScanKernel, a: &[f32], b: &[f32], a_norm2: f32, b_norm2: f32) -> f32 {
    match k {
        ScanKernel::Scalar => cosine_pre_dispatch(a, b, a_norm2, b_norm2),
        ScanKernel::Simd4 => cosine_finish(simd4::dot(a, b), a_norm2, b_norm2),
        ScanKernel::Simd8 => cosine_finish(simd8::dot(a, b), a_norm2, b_norm2),
    }
}

/// Kernel-dispatched row norm — MUST accumulate in the same order as the
/// matching kernel's fused `nb` term (hoisting invariance).
#[inline(always)]
fn row_norm2(k: ScanKernel, b: &[f32]) -> f32 {
    match k {
        ScanKernel::Scalar => norm2(b),
        ScanKernel::Simd4 => simd4::norm2(b),
        ScanKernel::Simd8 => simd8::norm2(b),
    }
}

#[inline(always)]
fn row_of(data: &[f32], id: u32, dim: usize) -> &[f32] {
    &data[id as usize * dim..id as usize * dim + dim]
}

impl NativeEngine {
    /// Shared body of the batched kernels: `next_id` yields candidate row
    /// ids in scan order; every query in the tile scores each row as it
    /// is loaded. Distances go through the engine's dispatched kernel —
    /// same kernel as the sequential path, so batched results stay
    /// bit-identical to it.
    #[inline(always)]
    fn batch_tiles<I>(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        ids: I,
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) where
        I: Iterator<Item = u32> + Clone,
    {
        let nq = topks.len();
        debug_assert_eq!(qs.len(), nq * dim);
        let k = self.kernel;
        match metric {
            Metric::L1 => {
                let mut qi = 0usize;
                while qi < nq {
                    let tile = (nq - qi).min(Q_TILE);
                    let tile_qs = &qs[qi * dim..(qi + tile) * dim];
                    for id in ids.clone() {
                        let row = row_of(data, id, dim);
                        for t in 0..tile {
                            let q = &tile_qs[t * dim..(t + 1) * dim];
                            let d = l1_row(k, q, row);
                            push_scored(&mut topks[qi + t], id_base, id, d, labels);
                        }
                    }
                    qi += tile;
                }
            }
            Metric::Cosine => {
                // Per-query squared norms, computed once per batch (plain
                // sequential sum — the exact expression the sequential
                // scan uses for its query norm, kernel-independent).
                let norms: Vec<f32> = (0..nq)
                    .map(|i| qs[i * dim..(i + 1) * dim].iter().map(|x| x * x).sum())
                    .collect();
                let mut qi = 0usize;
                while qi < nq {
                    let tile = (nq - qi).min(Q_TILE);
                    let tile_qs = &qs[qi * dim..(qi + tile) * dim];
                    for id in ids.clone() {
                        let row = row_of(data, id, dim);
                        // Row norm hoisted out of the tile: computed once
                        // per row load instead of once per query, in the
                        // kernel's own `nb` accumulation order.
                        let row_n2 = row_norm2(k, row);
                        for t in 0..tile {
                            let q = &tile_qs[t * dim..(t + 1) * dim];
                            let d = cosine_pre_row(k, q, row, norms[qi + t], row_n2);
                            push_scored(&mut topks[qi + t], id_base, id, d, labels);
                        }
                    }
                    qi += tile;
                }
            }
        }
    }
}

impl DistanceEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn scan(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        let k = self.kernel;
        match metric {
            Metric::L1 => {
                for &id in ids {
                    let d = l1_row(k, q, row_of(data, id, dim));
                    push_scored(topk, id_base, id, d, labels);
                }
            }
            Metric::Cosine => {
                let qn: f32 = q.iter().map(|x| x * x).sum();
                for &id in ids {
                    let d = cosine_row(k, q, row_of(data, id, dim), qn);
                    push_scored(topk, id_base, id, d, labels);
                }
            }
        }
        ids.len() as u64
    }

    fn scan_range(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        let k = self.kernel;
        let count = (range.end - range.start) as u64;
        match metric {
            Metric::L1 => {
                for id in range {
                    let d = l1_row(k, q, row_of(data, id, dim));
                    push_scored(topk, id_base, id, d, labels);
                }
            }
            Metric::Cosine => {
                let qn: f32 = q.iter().map(|x| x * x).sum();
                for id in range {
                    let d = cosine_row(k, q, row_of(data, id, dim), qn);
                    push_scored(topk, id_base, id, d, labels);
                }
            }
        }
        count
    }

    fn scan_batch(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) -> u64 {
        self.batch_tiles(metric, qs, data, dim, ids.iter().copied(), labels, id_base, topks);
        (topks.len() * ids.len()) as u64
    }

    fn scan_batch_range(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) -> u64 {
        let count = (range.end - range.start) as u64;
        self.batch_tiles(metric, qs, data, dim, range, labels, id_base, topks);
        count * topks.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{cosine_dist, l1_dist};
    use crate::util::rng::Xoshiro256;

    fn fixture(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.3)).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        (data, labels, q)
    }

    #[test]
    fn unrolled_matches_scalar_reference() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for dim in [1usize, 3, 4, 7, 30, 32, 33] {
            let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-5.0, 5.0) as f32).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-5.0, 5.0) as f32).collect();
            assert!((l1_unrolled(&a, &b) - l1_dist(&a, &b)).abs() < 1e-4, "dim={dim}");
            let an: f32 = a.iter().map(|x| x * x).sum();
            assert!(
                (cosine_unrolled(&a, &b, an) - cosine_dist(&a, &b)).abs() < 1e-5,
                "dim={dim}"
            );
        }
    }

    #[test]
    fn tail_dims_property_against_naive_reference() {
        // d ∈ {1, 3, 29, 31, 33, 37}: dims that exercise every remainder
        // class around the paper's widths. The unrolled scalar bodies
        // (which gate the SIMD remainder loops bit-for-bit) must agree
        // with the naive sequential oracle within reassociation
        // tolerance, on many random draws.
        let mut rng = Xoshiro256::seed_from_u64(77);
        for dim in [1usize, 3, 29, 31, 33, 37] {
            for _ in 0..300 {
                let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-80.0, 180.0) as f32).collect();
                let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-80.0, 180.0) as f32).collect();
                let l1 = l1_unrolled(&a, &b);
                let l1_ref = l1_dist(&a, &b);
                assert!(
                    (l1 - l1_ref).abs() <= 1e-4 * (1.0 + l1_ref.abs()),
                    "l1 dim={dim}: {l1} vs {l1_ref}"
                );
                let an: f32 = a.iter().map(|x| x * x).sum();
                let c = cosine_unrolled(&a, &b, an);
                let c_ref = cosine_dist(&a, &b);
                assert!((c - c_ref).abs() < 1e-5, "cosine dim={dim}: {c} vs {c_ref}");
                // The norm-precomputed split agrees with the fused body
                // exactly at tail dims too.
                assert_eq!(cosine_pre(&a, &b, an, norm2(&b)), c, "pre dim={dim}");
            }
        }
    }

    #[test]
    fn simd4_kernels_bit_identical_to_scalar_for_every_dim() {
        // Exhaustive d = 1..=67: covers both fixed-dim specializations,
        // every remainder class, and sub-quad lengths. On x86_64/aarch64
        // this gates the real SIMD kernels; elsewhere it is trivially the
        // scalar body (the fallback delegates).
        let mut rng = Xoshiro256::seed_from_u64(91);
        for dim in 1usize..=67 {
            for _ in 0..20 {
                let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-50.0, 150.0) as f32).collect();
                let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-50.0, 150.0) as f32).collect();
                assert_eq!(simd4::l1(&a, &b), l1_dist_dispatch(&a, &b), "l1 dim={dim}");
                let (dot, nb) = simd4::dot_nb(&a, &b);
                let (sdot, snb) = dot_nb_unrolled(&a, &b);
                assert_eq!(dot, sdot, "dot dim={dim}");
                assert_eq!(nb, snb, "nb dim={dim}");
                assert_eq!(simd4::dot(&a, &b), dot_unrolled(&a, &b), "pre-dot dim={dim}");
                assert_eq!(simd4::norm2(&b), norm2(&b), "norm2 dim={dim}");
                let an: f32 = a.iter().map(|x| x * x).sum();
                assert_eq!(
                    cosine_row(ScanKernel::Simd4, &a, &b, an),
                    cosine_dist_dispatch(&a, &b, an),
                    "cosine dim={dim}"
                );
            }
        }
        // Zero-vector guards behave identically through the SIMD arms.
        let z = vec![0.0f32; 31];
        let x = vec![1.0f32; 31];
        let xn: f32 = x.iter().map(|v| v * v).sum();
        assert_eq!(cosine_row(ScanKernel::Simd4, &x, &z, xn), 1.0);
        assert_eq!(cosine_row(ScanKernel::Simd4, &z, &x, 0.0), 1.0);
    }

    #[cfg(feature = "wide-simd")]
    #[test]
    fn simd8_within_tolerance_of_scalar() {
        if !ScanKernel::simd8_available() {
            eprintln!("skipping simd8 tolerance test: AVX2 not detected on this host");
            return;
        }
        let mut rng = Xoshiro256::seed_from_u64(93);
        for dim in [8usize, 29, 30, 32, 37, 64, 67] {
            for _ in 0..50 {
                let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-50.0, 150.0) as f32).collect();
                let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-50.0, 150.0) as f32).collect();
                let l1s = l1_dist_dispatch(&a, &b);
                let l1w = simd8::l1(&a, &b);
                assert!(
                    (l1w - l1s).abs() <= 1e-5 * (1.0 + l1s.abs()),
                    "l1 dim={dim}: {l1w} vs {l1s}"
                );
                let an: f32 = a.iter().map(|x| x * x).sum();
                let cs = cosine_dist_dispatch(&a, &b, an);
                let cw = cosine_row(ScanKernel::Simd8, &a, &b, an);
                assert!((cw - cs).abs() < 1e-5, "cosine dim={dim}: {cw} vs {cs}");
            }
        }
    }

    #[test]
    fn hoisted_row_norm_cosine_is_bit_identical() {
        // cosine_pre_dispatch(q, row, qn, norm2(row)) must equal the fused
        // cosine_dist_dispatch(q, row, qn) to the last bit, for both the
        // specialized and dynamic dims.
        let mut rng = Xoshiro256::seed_from_u64(12);
        for dim in [13usize, 30, 32] {
            for _ in 0..200 {
                let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-20.0, 180.0) as f32).collect();
                let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-20.0, 180.0) as f32).collect();
                let an: f32 = a.iter().map(|x| x * x).sum();
                assert_eq!(
                    cosine_pre_dispatch(&a, &b, an, norm2(&b)),
                    cosine_dist_dispatch(&a, &b, an),
                    "dim={dim}"
                );
                // Same invariance through the simd4 dispatch arms.
                assert_eq!(
                    cosine_pre_row(ScanKernel::Simd4, &a, &b, an, row_norm2(ScanKernel::Simd4, &b)),
                    cosine_row(ScanKernel::Simd4, &a, &b, an),
                    "simd4 dim={dim}"
                );
            }
        }
        // Zero-vector guards behave identically.
        let z = vec![0.0f32; 30];
        let x = vec![1.0f32; 30];
        let xn: f32 = x.iter().map(|v| v * v).sum();
        assert_eq!(cosine_pre_dispatch(&x, &z, xn, norm2(&z)), 1.0);
        assert_eq!(cosine_pre_dispatch(&z, &x, 0.0, norm2(&x)), 1.0);
    }

    #[test]
    fn fixed_dim_dispatch_is_bit_identical() {
        // The d=30/32 specializations must agree with the dynamic bodies
        // to the last bit (same accumulation order).
        let mut rng = Xoshiro256::seed_from_u64(9);
        for dim in [30usize, 32] {
            for _ in 0..200 {
                let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-50.0, 150.0) as f32).collect();
                let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-50.0, 150.0) as f32).collect();
                assert_eq!(l1_dist_dispatch(&a, &b), l1_unrolled(&a, &b), "dim={dim}");
                let an: f32 = a.iter().map(|x| x * x).sum();
                assert_eq!(
                    cosine_dist_dispatch(&a, &b, an),
                    cosine_unrolled(&a, &b, an),
                    "dim={dim}"
                );
            }
        }
    }

    #[test]
    fn engine_dispatch_is_bit_identical_scalar_vs_simd4() {
        // The engine-level gate: a default (runtime-dispatched) engine, a
        // pinned simd4 engine and a pinned scalar engine must agree bit
        // for bit on scan and scan_batch, both metrics, mixed dims.
        let scalar = NativeEngine::with_kernel(ScanKernel::Scalar);
        let simd = NativeEngine::with_kernel(ScanKernel::Simd4);
        let auto = NativeEngine::new();
        for dim in [13usize, 30, 31, 32] {
            let (data, labels, q) = fixture(240, dim, 42);
            let ids: Vec<u32> = (0..240).step_by(2).map(|i| i as u32).collect();
            for metric in [Metric::L1, Metric::Cosine] {
                let mut want = TopK::new(8);
                scalar.scan(metric, &q, &data, dim, &ids, &labels, 5, &mut want);
                let want = want.into_sorted();
                for eng in [&simd, &auto] {
                    let mut got = TopK::new(8);
                    eng.scan(metric, &q, &data, dim, &ids, &labels, 5, &mut got);
                    assert_eq!(got.into_sorted(), want, "dim={dim} metric={metric:?}");
                }
                let qs: Vec<f32> = q.iter().chain(q.iter()).chain(q.iter()).copied().collect();
                let mut want_b: Vec<TopK> = (0..3).map(|_| TopK::new(8)).collect();
                scalar.scan_batch(metric, &qs, &data, dim, &ids, &labels, 5, &mut want_b);
                let mut got_b: Vec<TopK> = (0..3).map(|_| TopK::new(8)).collect();
                simd.scan_batch(metric, &qs, &data, dim, &ids, &labels, 5, &mut got_b);
                for (w, g) in want_b.into_iter().zip(got_b) {
                    assert_eq!(g.into_sorted(), w.into_sorted(), "batch dim={dim}");
                }
            }
        }
    }

    #[test]
    fn kernel_detection_and_pinning() {
        assert_eq!(NativeEngine::new().kernel(), ScanKernel::detect());
        assert_eq!(NativeEngine::default().kernel(), ScanKernel::detect());
        assert_ne!(ScanKernel::detect(), ScanKernel::Simd8, "wide kernel is opt-in only");
        assert_eq!(NativeEngine::with_kernel(ScanKernel::Scalar).kernel(), ScanKernel::Scalar);
        #[cfg(not(feature = "wide-simd"))]
        assert!(!ScanKernel::simd8_available(), "simd8 requires the wide-simd feature");
    }

    #[test]
    fn scan_returns_count_and_correct_topk() {
        let (data, labels, q) = fixture(200, 30, 2);
        let engine = NativeEngine::new();
        let ids: Vec<u32> = (0..200).step_by(2).map(|i| i as u32).collect();
        let mut topk = TopK::new(5);
        let n = engine.scan(Metric::L1, &q, &data, 30, &ids, &labels, 1000, &mut topk);
        assert_eq!(n, ids.len() as u64);
        // Reference: full sort over the same candidates (same summation
        // order as the engine so ranks are comparable exactly).
        let mut reference: Vec<(f32, u64)> = ids
            .iter()
            .map(|&id| (l1_unrolled(&q, &data[id as usize * 30..id as usize * 30 + 30]), 1000 + id as u64))
            .collect();
        reference.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let got = topk.into_sorted();
        for (i, nb) in got.iter().enumerate() {
            assert_eq!(nb.id, reference[i].1, "rank {i}");
            assert!((nb.dist - reference[i].0).abs() < 1e-4);
        }
        // Labels carried through.
        for nb in &got {
            assert_eq!(nb.label, labels[(nb.id - 1000) as usize]);
        }
    }

    #[test]
    fn scan_range_equals_scan_with_ids() {
        let (data, labels, q) = fixture(128, 30, 3);
        let engine = NativeEngine::new();
        for metric in [Metric::L1, Metric::Cosine] {
            let mut a = TopK::new(7);
            let mut b = TopK::new(7);
            let ids: Vec<u32> = (10..90).collect();
            engine.scan(metric, &q, &data, 30, &ids, &labels, 0, &mut a);
            engine.scan_range(metric, &q, &data, 30, 10..90, &labels, 0, &mut b);
            assert_eq!(a.into_sorted(), b.into_sorted());
        }
    }

    #[test]
    fn scan_batch_is_bit_identical_to_sequential_scans() {
        // Odd dim (no fixed-dim specialization) and dim 30 (specialized),
        // batch sizes around the tile width, including 1 and non-multiples.
        let engine = NativeEngine::new();
        for dim in [13usize, 30] {
            let (data, labels, _) = fixture(300, dim, 4);
            let mut rng = Xoshiro256::seed_from_u64(5);
            for nq in [1usize, 2, 4, 5, 7, 16] {
                let qs: Vec<f32> =
                    (0..nq * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
                let ids: Vec<u32> = (0..300).step_by(3).map(|i| i as u32).collect();
                for metric in [Metric::L1, Metric::Cosine] {
                    let mut batched: Vec<TopK> = (0..nq).map(|_| TopK::new(6)).collect();
                    let total = engine
                        .scan_batch(metric, &qs, &data, dim, &ids, &labels, 70, &mut batched);
                    assert_eq!(total, (nq * ids.len()) as u64);
                    for qi in 0..nq {
                        let mut seq = TopK::new(6);
                        engine.scan(
                            metric,
                            &qs[qi * dim..(qi + 1) * dim],
                            &data,
                            dim,
                            &ids,
                            &labels,
                            70,
                            &mut seq,
                        );
                        // Exact equality — distances must match bit for bit.
                        assert_eq!(
                            batched[qi].clone().into_sorted(),
                            seq.into_sorted(),
                            "metric={metric:?} dim={dim} nq={nq} qi={qi}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn scan_batch_range_is_bit_identical_to_sequential_ranges() {
        let engine = NativeEngine::new();
        let dim = 30;
        let (data, labels, _) = fixture(500, dim, 6);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let nq = 6;
        let qs: Vec<f32> = (0..nq * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        for metric in [Metric::L1, Metric::Cosine] {
            let mut batched: Vec<TopK> = (0..nq).map(|_| TopK::new(9)).collect();
            let total =
                engine.scan_batch_range(metric, &qs, &data, dim, 17..441, &labels, 0, &mut batched);
            assert_eq!(total, (441 - 17) * nq as u64);
            for qi in 0..nq {
                let mut seq = TopK::new(9);
                engine.scan_range(
                    metric,
                    &qs[qi * dim..(qi + 1) * dim],
                    &data,
                    dim,
                    17..441,
                    &labels,
                    0,
                    &mut seq,
                );
                assert_eq!(batched[qi].clone().into_sorted(), seq.into_sorted(), "qi={qi}");
            }
        }
    }

    #[test]
    fn empty_ids_is_noop() {
        let (data, labels, q) = fixture(10, 30, 4);
        let engine = NativeEngine::new();
        let mut topk = TopK::new(3);
        let n = engine.scan(Metric::L1, &q, &data, 30, &[], &labels, 0, &mut topk);
        assert_eq!(n, 0);
        assert!(topk.is_empty());
        let mut topks = [TopK::new(3)];
        let n = engine.scan_batch(Metric::L1, &q, &data, 30, &[], &labels, 0, &mut topks);
        assert_eq!(n, 0);
        assert!(topks[0].is_empty());
    }
}
