//! Portable Rust distance engine.
//!
//! The scan is memory-bound (30 f32 per row); the implementation keeps the
//! inner loop branch-light and lets LLVM auto-vectorize the fixed-stride
//! accumulation. A 4-way unrolled accumulator breaks the fp dependence
//! chain, which matters on the d=30/32 rows the paper's datasets use.
//!
//! Two further levers on top of the scalar scan:
//!
//! * **Fixed-dim specialization** — d = 30 and d = 32 (the paper's window
//!   widths, plus the padded variant) dispatch to const-generic bodies
//!   with compile-time trip counts, so LLVM fully unrolls and vectorizes
//!   them. The arithmetic order is identical to the dynamic bodies, so
//!   distances are bit-identical across the dispatch.
//! * **Register-blocked query tiles** — `scan_batch`/`scan_batch_range`
//!   process [`Q_TILE`] queries per data-row load: each 30-f32 row is
//!   fetched from memory once per tile instead of once per query, which
//!   is where batched throughput comes from on shards that exceed cache.
//!   Per query, candidates are visited in the same order as the
//!   single-query scan and distances use the same summation order, so
//!   batched results are bit-identical to the sequential path.

use crate::engine::{push_scored, DistanceEngine, Metric};
use crate::knn::heap::TopK;

/// Queries processed per data-row load in the batched kernels.
pub const Q_TILE: usize = 4;

#[derive(Debug, Default, Clone)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        Self
    }
}

/// 4-accumulator L1 distance (dynamic length).
#[inline]
fn l1_unrolled(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += (a[j] - b[j]).abs();
        s1 += (a[j + 1] - b[j + 1]).abs();
        s2 += (a[j + 2] - b[j + 2]).abs();
        s3 += (a[j + 3] - b[j + 3]).abs();
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..n {
        tail += (a[j] - b[j]).abs();
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Const-length twin of [`l1_unrolled`] — same accumulation order, so the
/// result is bit-identical; the constant trip count lets LLVM fully
/// unroll + vectorize.
#[inline(always)]
fn l1_fixed<const D: usize>(a: &[f32; D], b: &[f32; D]) -> f32 {
    let chunks = D / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += (a[j] - b[j]).abs();
        s1 += (a[j + 1] - b[j + 1]).abs();
        s2 += (a[j + 2] - b[j + 2]).abs();
        s3 += (a[j + 3] - b[j + 3]).abs();
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..D {
        tail += (a[j] - b[j]).abs();
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Dim-dispatching L1: specialized for the paper's 30-wide windows (and
/// the 32-wide padded layout), dynamic otherwise. Bit-identical across
/// arms by construction.
#[inline(always)]
fn l1_dist_dispatch(a: &[f32], b: &[f32]) -> f32 {
    match a.len() {
        30 => l1_fixed::<30>(a.try_into().unwrap(), b.try_into().unwrap()),
        32 => l1_fixed::<32>(a.try_into().unwrap(), b.try_into().unwrap()),
        _ => l1_unrolled(a, b),
    }
}

/// Fused dot/norm accumulation for cosine (dynamic length).
#[inline]
fn cosine_unrolled(a: &[f32], b: &[f32], a_norm2: f32) -> f32 {
    let mut dot = 0.0f32;
    let mut nb = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        nb += y * y;
    }
    if a_norm2 == 0.0 || nb == 0.0 {
        return 1.0;
    }
    1.0 - dot / (a_norm2.sqrt() * nb.sqrt())
}

/// Const-length twin of [`cosine_unrolled`] — identical accumulation
/// order, bit-identical result.
#[inline(always)]
fn cosine_fixed<const D: usize>(a: &[f32; D], b: &[f32; D], a_norm2: f32) -> f32 {
    let mut dot = 0.0f32;
    let mut nb = 0.0f32;
    for j in 0..D {
        dot += a[j] * b[j];
        nb += b[j] * b[j];
    }
    if a_norm2 == 0.0 || nb == 0.0 {
        return 1.0;
    }
    1.0 - dot / (a_norm2.sqrt() * nb.sqrt())
}

#[inline(always)]
fn cosine_dist_dispatch(a: &[f32], b: &[f32], a_norm2: f32) -> f32 {
    match a.len() {
        30 => cosine_fixed::<30>(a.try_into().unwrap(), b.try_into().unwrap(), a_norm2),
        32 => cosine_fixed::<32>(a.try_into().unwrap(), b.try_into().unwrap(), a_norm2),
        _ => cosine_unrolled(a, b, a_norm2),
    }
}

/// Squared norm accumulated in index order — the exact order the fused
/// kernels accumulate their `nb` term, so hoisting a row's norm out of
/// the query tile is bit-identical.
#[inline(always)]
fn norm2(b: &[f32]) -> f32 {
    let mut nb = 0.0f32;
    for y in b {
        nb += y * y;
    }
    nb
}

/// Cosine with BOTH norms precomputed; the dot product uses the same
/// index-order accumulation as the fused kernels and the final
/// expression is unchanged, so the result is bit-identical to
/// [`cosine_dist_dispatch`] — while each row's norm is computed once per
/// row load instead of once per (query, row) pair.
#[inline(always)]
fn cosine_pre(a: &[f32], b: &[f32], a_norm2: f32, b_norm2: f32) -> f32 {
    let mut dot = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
    }
    if a_norm2 == 0.0 || b_norm2 == 0.0 {
        return 1.0;
    }
    1.0 - dot / (a_norm2.sqrt() * b_norm2.sqrt())
}

#[inline(always)]
fn cosine_pre_fixed<const D: usize>(a: &[f32; D], b: &[f32; D], a_norm2: f32, b_norm2: f32) -> f32 {
    let mut dot = 0.0f32;
    for j in 0..D {
        dot += a[j] * b[j];
    }
    if a_norm2 == 0.0 || b_norm2 == 0.0 {
        return 1.0;
    }
    1.0 - dot / (a_norm2.sqrt() * b_norm2.sqrt())
}

#[inline(always)]
fn cosine_pre_dispatch(a: &[f32], b: &[f32], a_norm2: f32, b_norm2: f32) -> f32 {
    match a.len() {
        30 => cosine_pre_fixed::<30>(a.try_into().unwrap(), b.try_into().unwrap(), a_norm2, b_norm2),
        32 => cosine_pre_fixed::<32>(a.try_into().unwrap(), b.try_into().unwrap(), a_norm2, b_norm2),
        _ => cosine_pre(a, b, a_norm2, b_norm2),
    }
}

#[inline(always)]
fn row_of(data: &[f32], id: u32, dim: usize) -> &[f32] {
    &data[id as usize * dim..id as usize * dim + dim]
}

impl NativeEngine {
    /// Shared body of the batched kernels: `next_id` yields candidate row
    /// ids in scan order; every query in the tile scores each row as it
    /// is loaded.
    #[inline(always)]
    fn batch_tiles<I>(
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        ids: I,
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) where
        I: Iterator<Item = u32> + Clone,
    {
        let nq = topks.len();
        debug_assert_eq!(qs.len(), nq * dim);
        match metric {
            Metric::L1 => {
                let mut qi = 0usize;
                while qi < nq {
                    let tile = (nq - qi).min(Q_TILE);
                    let tile_qs = &qs[qi * dim..(qi + tile) * dim];
                    for id in ids.clone() {
                        let row = row_of(data, id, dim);
                        for t in 0..tile {
                            let q = &tile_qs[t * dim..(t + 1) * dim];
                            let d = l1_dist_dispatch(q, row);
                            push_scored(&mut topks[qi + t], id_base, id, d, labels);
                        }
                    }
                    qi += tile;
                }
            }
            Metric::Cosine => {
                // Per-query squared norms, computed once per batch.
                let norms: Vec<f32> = (0..nq)
                    .map(|i| qs[i * dim..(i + 1) * dim].iter().map(|x| x * x).sum())
                    .collect();
                let mut qi = 0usize;
                while qi < nq {
                    let tile = (nq - qi).min(Q_TILE);
                    let tile_qs = &qs[qi * dim..(qi + tile) * dim];
                    for id in ids.clone() {
                        let row = row_of(data, id, dim);
                        // Row norm hoisted out of the tile: computed once
                        // per row load instead of once per query.
                        let row_n2 = norm2(row);
                        for t in 0..tile {
                            let q = &tile_qs[t * dim..(t + 1) * dim];
                            let d = cosine_pre_dispatch(q, row, norms[qi + t], row_n2);
                            push_scored(&mut topks[qi + t], id_base, id, d, labels);
                        }
                    }
                    qi += tile;
                }
            }
        }
    }
}

impl DistanceEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn scan(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        match metric {
            Metric::L1 => {
                for &id in ids {
                    let d = l1_dist_dispatch(q, row_of(data, id, dim));
                    push_scored(topk, id_base, id, d, labels);
                }
            }
            Metric::Cosine => {
                let qn: f32 = q.iter().map(|x| x * x).sum();
                for &id in ids {
                    let d = cosine_dist_dispatch(q, row_of(data, id, dim), qn);
                    push_scored(topk, id_base, id, d, labels);
                }
            }
        }
        ids.len() as u64
    }

    fn scan_range(
        &self,
        metric: Metric,
        q: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topk: &mut TopK,
    ) -> u64 {
        let count = (range.end - range.start) as u64;
        match metric {
            Metric::L1 => {
                for id in range {
                    let d = l1_dist_dispatch(q, row_of(data, id, dim));
                    push_scored(topk, id_base, id, d, labels);
                }
            }
            Metric::Cosine => {
                let qn: f32 = q.iter().map(|x| x * x).sum();
                for id in range {
                    let d = cosine_dist_dispatch(q, row_of(data, id, dim), qn);
                    push_scored(topk, id_base, id, d, labels);
                }
            }
        }
        count
    }

    fn scan_batch(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        ids: &[u32],
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) -> u64 {
        Self::batch_tiles(metric, qs, data, dim, ids.iter().copied(), labels, id_base, topks);
        (topks.len() * ids.len()) as u64
    }

    fn scan_batch_range(
        &self,
        metric: Metric,
        qs: &[f32],
        data: &[f32],
        dim: usize,
        range: std::ops::Range<u32>,
        labels: &[bool],
        id_base: u64,
        topks: &mut [TopK],
    ) -> u64 {
        let count = (range.end - range.start) as u64;
        Self::batch_tiles(metric, qs, data, dim, range, labels, id_base, topks);
        count * topks.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{cosine_dist, l1_dist};
    use crate::util::rng::Xoshiro256;

    fn fixture(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.3)).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        (data, labels, q)
    }

    #[test]
    fn unrolled_matches_scalar_reference() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for dim in [1usize, 3, 4, 7, 30, 32, 33] {
            let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-5.0, 5.0) as f32).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-5.0, 5.0) as f32).collect();
            assert!((l1_unrolled(&a, &b) - l1_dist(&a, &b)).abs() < 1e-4, "dim={dim}");
            let an: f32 = a.iter().map(|x| x * x).sum();
            assert!(
                (cosine_unrolled(&a, &b, an) - cosine_dist(&a, &b)).abs() < 1e-5,
                "dim={dim}"
            );
        }
    }

    #[test]
    fn hoisted_row_norm_cosine_is_bit_identical() {
        // cosine_pre_dispatch(q, row, qn, norm2(row)) must equal the fused
        // cosine_dist_dispatch(q, row, qn) to the last bit, for both the
        // specialized and dynamic dims.
        let mut rng = Xoshiro256::seed_from_u64(12);
        for dim in [13usize, 30, 32] {
            for _ in 0..200 {
                let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-20.0, 180.0) as f32).collect();
                let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-20.0, 180.0) as f32).collect();
                let an: f32 = a.iter().map(|x| x * x).sum();
                assert_eq!(
                    cosine_pre_dispatch(&a, &b, an, norm2(&b)),
                    cosine_dist_dispatch(&a, &b, an),
                    "dim={dim}"
                );
            }
        }
        // Zero-vector guards behave identically.
        let z = vec![0.0f32; 30];
        let x = vec![1.0f32; 30];
        let xn: f32 = x.iter().map(|v| v * v).sum();
        assert_eq!(cosine_pre_dispatch(&x, &z, xn, norm2(&z)), 1.0);
        assert_eq!(cosine_pre_dispatch(&z, &x, 0.0, norm2(&x)), 1.0);
    }

    #[test]
    fn fixed_dim_dispatch_is_bit_identical() {
        // The d=30/32 specializations must agree with the dynamic bodies
        // to the last bit (same accumulation order).
        let mut rng = Xoshiro256::seed_from_u64(9);
        for dim in [30usize, 32] {
            for _ in 0..200 {
                let a: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-50.0, 150.0) as f32).collect();
                let b: Vec<f32> = (0..dim).map(|_| rng.gen_f64(-50.0, 150.0) as f32).collect();
                assert_eq!(l1_dist_dispatch(&a, &b), l1_unrolled(&a, &b), "dim={dim}");
                let an: f32 = a.iter().map(|x| x * x).sum();
                assert_eq!(
                    cosine_dist_dispatch(&a, &b, an),
                    cosine_unrolled(&a, &b, an),
                    "dim={dim}"
                );
            }
        }
    }

    #[test]
    fn scan_returns_count_and_correct_topk() {
        let (data, labels, q) = fixture(200, 30, 2);
        let engine = NativeEngine::new();
        let ids: Vec<u32> = (0..200).step_by(2).map(|i| i as u32).collect();
        let mut topk = TopK::new(5);
        let n = engine.scan(Metric::L1, &q, &data, 30, &ids, &labels, 1000, &mut topk);
        assert_eq!(n, ids.len() as u64);
        // Reference: full sort over the same candidates (same summation
        // order as the engine so ranks are comparable exactly).
        let mut reference: Vec<(f32, u64)> = ids
            .iter()
            .map(|&id| (l1_unrolled(&q, &data[id as usize * 30..id as usize * 30 + 30]), 1000 + id as u64))
            .collect();
        reference.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let got = topk.into_sorted();
        for (i, nb) in got.iter().enumerate() {
            assert_eq!(nb.id, reference[i].1, "rank {i}");
            assert!((nb.dist - reference[i].0).abs() < 1e-4);
        }
        // Labels carried through.
        for nb in &got {
            assert_eq!(nb.label, labels[(nb.id - 1000) as usize]);
        }
    }

    #[test]
    fn scan_range_equals_scan_with_ids() {
        let (data, labels, q) = fixture(128, 30, 3);
        let engine = NativeEngine::new();
        for metric in [Metric::L1, Metric::Cosine] {
            let mut a = TopK::new(7);
            let mut b = TopK::new(7);
            let ids: Vec<u32> = (10..90).collect();
            engine.scan(metric, &q, &data, 30, &ids, &labels, 0, &mut a);
            engine.scan_range(metric, &q, &data, 30, 10..90, &labels, 0, &mut b);
            assert_eq!(a.into_sorted(), b.into_sorted());
        }
    }

    #[test]
    fn scan_batch_is_bit_identical_to_sequential_scans() {
        // Odd dim (no fixed-dim specialization) and dim 30 (specialized),
        // batch sizes around the tile width, including 1 and non-multiples.
        let engine = NativeEngine::new();
        for dim in [13usize, 30] {
            let (data, labels, _) = fixture(300, dim, 4);
            let mut rng = Xoshiro256::seed_from_u64(5);
            for nq in [1usize, 2, 4, 5, 7, 16] {
                let qs: Vec<f32> =
                    (0..nq * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
                let ids: Vec<u32> = (0..300).step_by(3).map(|i| i as u32).collect();
                for metric in [Metric::L1, Metric::Cosine] {
                    let mut batched: Vec<TopK> = (0..nq).map(|_| TopK::new(6)).collect();
                    let total = engine
                        .scan_batch(metric, &qs, &data, dim, &ids, &labels, 70, &mut batched);
                    assert_eq!(total, (nq * ids.len()) as u64);
                    for qi in 0..nq {
                        let mut seq = TopK::new(6);
                        engine.scan(
                            metric,
                            &qs[qi * dim..(qi + 1) * dim],
                            &data,
                            dim,
                            &ids,
                            &labels,
                            70,
                            &mut seq,
                        );
                        // Exact equality — distances must match bit for bit.
                        assert_eq!(
                            batched[qi].clone().into_sorted(),
                            seq.into_sorted(),
                            "metric={metric:?} dim={dim} nq={nq} qi={qi}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn scan_batch_range_is_bit_identical_to_sequential_ranges() {
        let engine = NativeEngine::new();
        let dim = 30;
        let (data, labels, _) = fixture(500, dim, 6);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let nq = 6;
        let qs: Vec<f32> = (0..nq * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        for metric in [Metric::L1, Metric::Cosine] {
            let mut batched: Vec<TopK> = (0..nq).map(|_| TopK::new(9)).collect();
            let total =
                engine.scan_batch_range(metric, &qs, &data, dim, 17..441, &labels, 0, &mut batched);
            assert_eq!(total, (441 - 17) * nq as u64);
            for qi in 0..nq {
                let mut seq = TopK::new(9);
                engine.scan_range(
                    metric,
                    &qs[qi * dim..(qi + 1) * dim],
                    &data,
                    dim,
                    17..441,
                    &labels,
                    0,
                    &mut seq,
                );
                assert_eq!(batched[qi].clone().into_sorted(), seq.into_sorted(), "qi={qi}");
            }
        }
    }

    #[test]
    fn empty_ids_is_noop() {
        let (data, labels, q) = fixture(10, 30, 4);
        let engine = NativeEngine::new();
        let mut topk = TopK::new(3);
        let n = engine.scan(Metric::L1, &q, &data, 30, &[], &labels, 0, &mut topk);
        assert_eq!(n, 0);
        assert!(topk.is_empty());
        let mut topks = [TopK::new(3)];
        let n = engine.scan_batch(Metric::L1, &q, &data, 30, &[], &labels, 0, &mut topks);
        assert_eq!(n, 0);
        assert!(topks[0].is_empty());
    }
}
