//! The distributed coordination layer (paper Figure 1): the Orchestrator's
//! Root / Forwarder / Reducer processes and cluster assembly.

pub mod cluster;
pub mod orchestrator;

pub use cluster::{build_cluster, Cluster, ClusterConfig, EngineKind};
pub use orchestrator::{NodeHandle, Orchestrator, QueryResult};
