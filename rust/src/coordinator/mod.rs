//! The distributed coordination layer (paper Figure 1): the Orchestrator's
//! Root / Forwarder / Reducer processes, the deadline-aware admission
//! queue in front of them, and cluster assembly with per-shard replica
//! groups.
//!
//! # The QuerySpec contract
//!
//! Every query enters through ONE typed operating point, [`QuerySpec`]:
//! `query_spec` / `query_batch_spec_flat` on the direct path,
//! `submit_spec` / `try_submit_spec` on the admission path, the wire's
//! `QueryBatchBudget` frame between processes, and the HTTP edge's
//! `POST /v1/query` body at the front door all carry the same fields.
//! `QuerySpec::default()` is *exactly* the pre-spec behavior — no
//! deadline, one probe per table, no comparison cap, the cluster's K —
//! so the positional entry points (now thin deprecated shims) and the
//! spec door are bit-identical when no knob is turned.
//!
//! What each knob means at each layer, and what is guaranteed:
//!
//! | Knob | Admission layer | Node/scan layer | Guarantee |
//! |------|-----------------|-----------------|-----------|
//! | `class` | picks the lane: monitor has strict priority, analytics rides leftovers with aging protection | — | lane isolation is pinned by `admission_priority` tests |
//! | `budget` | drives the deadline cutter (when to dispatch); `None` = ride cuts, never force one | armed as the scan deadline from dispatch | deadline never inflates: a shared cut uses the *earliest* rider deadline |
//! | `policy` | riders escalate the cut's policy; the configured [`AdmissionConfig`] policy is the floor | decides what an overrun does: log, truncate (`partial`), or shed | strictest rider governs — a `shed` rider is never silently degraded to `log_only` |
//! | `probes` | cut uses the *widest* rider request; `0` = lane default (feedback-controlled under [`AutoProbes`]) | each outer table visits that many buckets in margin order | candidate set is monotone non-decreasing in `probes` (probe sequences are prefixes) |
//! | `recall_hint` | mapped to a probe count before admission (mutually exclusive with `probes`) | as `probes` | same monotonicity, declarative dial |
//! | `max_comparisons` | cut uses the *tightest* nonzero rider cap | hard per-worker candidate budget; truncation flags `partial` | deterministic (clock-free), reproducible under any scheduler |
//! | `k` | returned-neighbor truncation at fulfillment | — | prediction/vote always uses the full cluster K-NN; `k` is display-only |
//!
//! Resolution on a shared admission cut is conservative per axis
//! (earliest deadline, strictest policy, widest probes, tightest cap) so
//! no rider ever gets *less* than it asked for on its own accuracy axis,
//! and none can relax another rider's safety axis.
//!
//! # Failure-semantics contract
//!
//! The coordination layer's promise to callers, in order of strength:
//!
//! 1. **No panic, no hang.** Node death never aborts the process or
//!    stalls a query: every shard dispatcher guarantees exactly one reply
//!    per (shard, query), synthesizing a shed reply when no replica can
//!    answer. The only caller-visible error on the query path is
//!    [`ClusterError::Shutdown`] — the cluster itself was dropped.
//! 2. **Degrade, don't wait.** A dead or straggling replica is routed
//!    around: hedged to a sibling after
//!    [`FailoverConfig::hedge_after`], failed over on transport error,
//!    and written off (shed) at [`FailoverConfig::request_timeout`]. The
//!    caller reads the damage from [`QueryResult::shed_nodes`] /
//!    [`QueryResult::partial`] — the same vocabulary node-side budget
//!    enforcement uses, because "a shard contributed nothing" means the
//!    same thing to a monitor either way.
//! 3. **Never silently drop ingest.** Inserts fan out to every live
//!    replica of the target shard; zero acknowledgements is a hard
//!    [`ClusterError::ShardUnavailable`], and partial replication is
//!    visible in [`InsertOutcome::replicas_acked`].
//! 4. **Health is observable and recoverable.** Replicas move `Up` →
//!    `Suspect` → `Down` ([`Health`]) on request outcomes and
//!    heartbeats; `Down` replicas are re-dialed on a capped, jittered
//!    exponential backoff ([`FailoverConfig::reconnect_delay`]). All of
//!    it is metered ([`Orchestrator::failover_stats`]) and every timing
//!    decision reads the injectable clock, so the whole contract is
//!    pinned by deterministic tests (`rust/tests/fault_tolerance.rs`).

pub mod admission;
pub mod cluster;
pub mod orchestrator;

pub use admission::{
    completion_slot, note_batch_overrun, AdmissionConfig, AdmissionError, AdmissionQueue,
    AdmissionStats, AutoProbes, Budget, BudgetPolicy, Class, Clock, CutReason, LaneStats,
    MockClock, SystemClock, TickClock, Ticket,
};
pub use cluster::{
    build_cluster, build_live_cluster, Cluster, ClusterConfig, EngineKind, FailoverConfig, Health,
    ReplicaSet,
};
pub use orchestrator::{
    ClusterError, InsertOutcome, NodeError, NodeHandle, Orchestrator, QueryResult, QuerySpec,
    NO_BUDGET,
};
