//! The distributed coordination layer (paper Figure 1): the Orchestrator's
//! Root / Forwarder / Reducer processes, the deadline-aware admission
//! queue in front of them, and cluster assembly.

pub mod admission;
pub mod cluster;
pub mod orchestrator;

pub use admission::{
    completion_slot, note_batch_overrun, AdmissionConfig, AdmissionError, AdmissionQueue,
    AdmissionStats, Budget, BudgetPolicy, Class, Clock, CutReason, LaneStats, MockClock,
    SystemClock, TickClock, Ticket,
};
pub use cluster::{build_cluster, build_live_cluster, Cluster, ClusterConfig, EngineKind};
pub use orchestrator::{InsertOutcome, NodeHandle, Orchestrator, QueryResult, NO_BUDGET};
