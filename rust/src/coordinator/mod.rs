//! The distributed coordination layer (paper Figure 1): the Orchestrator's
//! Root / Forwarder / Reducer processes, the deadline-aware admission
//! queue in front of them, and cluster assembly with per-shard replica
//! groups.
//!
//! # Failure-semantics contract
//!
//! The coordination layer's promise to callers, in order of strength:
//!
//! 1. **No panic, no hang.** Node death never aborts the process or
//!    stalls a query: every shard dispatcher guarantees exactly one reply
//!    per (shard, query), synthesizing a shed reply when no replica can
//!    answer. The only caller-visible error on the query path is
//!    [`ClusterError::Shutdown`] — the cluster itself was dropped.
//! 2. **Degrade, don't wait.** A dead or straggling replica is routed
//!    around: hedged to a sibling after
//!    [`FailoverConfig::hedge_after`], failed over on transport error,
//!    and written off (shed) at [`FailoverConfig::request_timeout`]. The
//!    caller reads the damage from [`QueryResult::shed_nodes`] /
//!    [`QueryResult::partial`] — the same vocabulary node-side budget
//!    enforcement uses, because "a shard contributed nothing" means the
//!    same thing to a monitor either way.
//! 3. **Never silently drop ingest.** Inserts fan out to every live
//!    replica of the target shard; zero acknowledgements is a hard
//!    [`ClusterError::ShardUnavailable`], and partial replication is
//!    visible in [`InsertOutcome::replicas_acked`].
//! 4. **Health is observable and recoverable.** Replicas move `Up` →
//!    `Suspect` → `Down` ([`Health`]) on request outcomes and
//!    heartbeats; `Down` replicas are re-dialed on a capped, jittered
//!    exponential backoff ([`FailoverConfig::reconnect_delay`]). All of
//!    it is metered ([`Orchestrator::failover_stats`]) and every timing
//!    decision reads the injectable clock, so the whole contract is
//!    pinned by deterministic tests (`rust/tests/fault_tolerance.rs`).

pub mod admission;
pub mod cluster;
pub mod orchestrator;

pub use admission::{
    completion_slot, note_batch_overrun, AdmissionConfig, AdmissionError, AdmissionQueue,
    AdmissionStats, Budget, BudgetPolicy, Class, Clock, CutReason, LaneStats, MockClock,
    SystemClock, TickClock, Ticket,
};
pub use cluster::{
    build_cluster, build_live_cluster, Cluster, ClusterConfig, EngineKind, FailoverConfig, Health,
    ReplicaSet,
};
pub use orchestrator::{
    ClusterError, InsertOutcome, NodeError, NodeHandle, Orchestrator, QueryResult, NO_BUDGET,
};
