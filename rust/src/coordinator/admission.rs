//! Deadline-aware async admission queue for the batched query path.
//!
//! The paper's ICU use case prioritizes latency over throughput, but after
//! the batched pipeline landed, the cluster only saw a batch when a single
//! caller handed [`Orchestrator::query_batch`] a pre-formed block —
//! concurrent ICU monitors each paid the full per-dispatch cost and never
//! shared a scan. This module is the admission layer that coalesces
//! *independent* callers into batches under a latency budget:
//!
//! * Callers [`submit`](AdmissionQueue::submit) one query plus a latency
//!   budget and get a [`Ticket`] back; [`Ticket::wait`] blocks on a
//!   per-request one-shot completion slot ([`completion_slot`]) — the
//!   reply path is lock-free (atomic state + `thread::park`, no mutex).
//! * A dedicated **cutter** thread watches the bounded FIFO and dispatches
//!   a batch when it reaches `max_batch` ([`CutReason::Fill`]) **or** the
//!   earliest pending deadline expires ([`CutReason::Deadline`]) —
//!   whichever comes first. A deadline cut always takes *every* pending
//!   request (pending < `max_batch`, else it would have fill-cut), so the
//!   most urgent request is always in the batch it triggers.
//! * The queue is bounded: when `queue_cap` requests are pending,
//!   [`submit`](AdmissionQueue::submit) blocks and
//!   [`try_submit`](AdmissionQueue::try_submit) returns
//!   [`AdmissionError::QueueFull`] — backpressure, never silent drops.
//! * Shutdown (dropping the queue) drains: every in-flight request is
//!   dispatched in [`CutReason::Drain`] cuts before the cutter exits, so
//!   no ticket is ever left hanging.
//!
//! Dispatch rides [`Orchestrator::query_batch`]'s flat-block path, so a
//! coalesced batch reuses the per-core `QueryScratch`/`BatchOutput` arenas
//! downstream exactly like a caller-formed block, and the remaining budget
//! of the most urgent request travels with the cut (the TCP wire ships it
//! in a `QueryBatchBudget` frame so remote nodes can honor the same cut).
//!
//! **Determinism.** The cutter never reads the wall clock directly: it
//! takes a [`Clock`] (real [`SystemClock`] or test [`MockClock`]), and the
//! optional per-request deadline jitter (used to de-synchronize fleets of
//! periodic monitors) draws from an RNG seeded by
//! [`AdmissionConfig::seed`] — every batching decision is a pure function
//! of (submission order, clock readings, seed), reproducible in tests
//! with no sleeps. Observability is shared with the rest of the serving
//! stack: queue depth through [`QueueStats`] and the cut-reason mix
//! through [`CutCounters`], both defined in
//! [`crate::runtime::service`].
//!
//! **Known limit: one batch in flight.** The cutter dispatches
//! synchronously (the Root resolves one batch at a time anyway), so a
//! deadline falling due *while a batch is on the cluster* fires only
//! when the dispatch returns — under sustained load a tight budget can
//! be overrun by up to one batch service time, and the overrun is not
//! distinguished in the counters (the cut is still recorded as
//! `Deadline`). Budgets are therefore targets the cutter never
//! *undershoots*, not hard guarantees; pipelined dispatch / priority
//! lanes are the follow-up that tightens this (see ROADMAP).
//!
//! This queue is the architectural seam all later scheduling work
//! (priority classes, NUMA pinning) plugs into: those features change
//! *which* requests a cut takes, not how callers submit or wait.
//!
//! [`Orchestrator::query_batch`]: crate::coordinator::Orchestrator::query_batch

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::orchestrator::QueryResult;
use crate::runtime::service::{CutCounters, QueueStats};
use crate::util::rng::Xoshiro256;

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

/// Monotonic time source for batching decisions. Injecting it is what
/// makes every cutter decision reproducible in tests.
pub trait Clock: Send + Sync {
    /// Nanoseconds since an arbitrary fixed origin. Must be monotone.
    fn now_ns(&self) -> u64;
}

/// Production clock: monotonic nanoseconds since construction.
#[derive(Debug)]
pub struct SystemClock {
    origin: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { origin: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> SystemClock {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// Test clock: time only moves when the test says so.
#[derive(Debug, Default)]
pub struct MockClock {
    ns: AtomicU64,
}

impl MockClock {
    pub fn new(start_ns: u64) -> MockClock {
        MockClock { ns: AtomicU64::new(start_ns) }
    }

    pub fn set_ns(&self, t: u64) {
        self.ns.store(t, Ordering::SeqCst);
    }

    pub fn advance_ns(&self, d: u64) {
        self.ns.fetch_add(d, Ordering::SeqCst);
    }

    pub fn advance(&self, d: Duration) {
        self.advance_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }
}

impl Clock for MockClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::SeqCst)
    }
}

// ---------------------------------------------------------------------------
// One-shot completion slot (the lock-free reply path)
// ---------------------------------------------------------------------------

const SLOT_EMPTY: u8 = 0;
const SLOT_WAITING: u8 = 1;
const SLOT_FULL: u8 = 2;
const SLOT_CLOSED: u8 = 3;

struct OneShot<T> {
    state: AtomicU8,
    value: UnsafeCell<Option<T>>,
    waiter: UnsafeCell<Option<std::thread::Thread>>,
}

// SAFETY: the cells are only touched under the state-machine protocol
// below — `value` is written by the single writer before the Release
// transition to FULL and read by the single reader after an Acquire load
// of FULL; `waiter` is written by the single reader before its Release
// CAS to WAITING and read by the single writer only after an Acquire
// observation of WAITING. `SlotWriter`/`SlotReader` are not Clone and
// their operations consume `self`, so single-writer/single-reader holds
// in safe code.
unsafe impl<T: Send> Send for OneShot<T> {}
unsafe impl<T: Send> Sync for OneShot<T> {}

/// Producer half of a one-shot completion slot.
pub struct SlotWriter<T>(Arc<OneShot<T>>);

/// Consumer half of a one-shot completion slot.
pub struct SlotReader<T>(Arc<OneShot<T>>);

/// A single-producer single-consumer, one-shot, lock-free handoff cell:
/// `fulfill` publishes a value with one atomic swap; `wait` parks the
/// calling thread until the value (or a writer-dropped signal) arrives.
/// This is the admission queue's reply path — no mutex is ever taken
/// between the cutter finishing a batch and a caller waking up.
pub fn completion_slot<T: Send>() -> (SlotWriter<T>, SlotReader<T>) {
    let shared = Arc::new(OneShot {
        state: AtomicU8::new(SLOT_EMPTY),
        value: UnsafeCell::new(None),
        waiter: UnsafeCell::new(None),
    });
    (SlotWriter(Arc::clone(&shared)), SlotReader(shared))
}

impl<T: Send> SlotWriter<T> {
    /// Publish the value and wake the reader (if it is already parked).
    pub fn fulfill(self, v: T) {
        let s = &self.0;
        // SAFETY: single writer, and the reader cannot touch `value`
        // until it observes FULL (published by the swap below).
        unsafe { *s.value.get() = Some(v) };
        let prev = s.state.swap(SLOT_FULL, Ordering::AcqRel);
        debug_assert!(prev == SLOT_EMPTY || prev == SLOT_WAITING, "one-shot fulfilled twice");
        if prev == SLOT_WAITING {
            // SAFETY: the reader wrote `waiter` before its Release CAS to
            // WAITING, which we just Acquire-observed; it will not write
            // again.
            if let Some(t) = unsafe { (*s.waiter.get()).take() } {
                t.unpark();
            }
        }
        // Drop of `self` sees FULL and leaves the cell alone.
    }
}

impl<T> Drop for SlotWriter<T> {
    fn drop(&mut self) {
        // Writer going away without fulfilling: close the slot so the
        // reader unblocks with `None` instead of hanging forever.
        let s = &self.0;
        let mut cur = s.state.load(Ordering::Acquire);
        loop {
            if cur == SLOT_FULL || cur == SLOT_CLOSED {
                return;
            }
            match s.state.compare_exchange(cur, SLOT_CLOSED, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => {
                    if cur == SLOT_WAITING {
                        // SAFETY: same visibility argument as in `fulfill`.
                        if let Some(t) = unsafe { (*s.waiter.get()).take() } {
                            t.unpark();
                        }
                    }
                    return;
                }
                Err(actual) => cur = actual,
            }
        }
    }
}

impl<T: Send> SlotReader<T> {
    /// Block until the writer fulfills the slot (`Some`) or drops without
    /// fulfilling it (`None`).
    pub fn wait(self) -> Option<T> {
        let s = &self.0;
        let mut cur = s.state.load(Ordering::Acquire);
        if cur == SLOT_EMPTY {
            // Register for wakeup, then re-check: the writer may have
            // raced past between the load and the CAS.
            // SAFETY: single reader; the writer only reads `waiter` after
            // observing WAITING, which this CAS publishes.
            unsafe { *s.waiter.get() = Some(std::thread::current()) };
            match s.state.compare_exchange(
                SLOT_EMPTY,
                SLOT_WAITING,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => loop {
                    cur = s.state.load(Ordering::Acquire);
                    if cur == SLOT_FULL || cur == SLOT_CLOSED {
                        break;
                    }
                    std::thread::park();
                },
                Err(actual) => cur = actual,
            }
        }
        match cur {
            // SAFETY: FULL was published after the writer's value store.
            SLOT_FULL => unsafe { (*s.value.get()).take() },
            SLOT_CLOSED => None,
            _ => unreachable!("one-shot left in transient state"),
        }
    }
}

// ---------------------------------------------------------------------------
// Admission queue
// ---------------------------------------------------------------------------

/// Admission-layer configuration.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Query dimensionality (every submission is checked against it —
    /// a ragged batch flattened as-if-rectangular would scan garbage).
    pub dim: usize,
    /// Cut a batch as soon as this many requests are pending.
    pub max_batch: usize,
    /// Bounded-queue capacity; beyond it, `submit` blocks (backpressure).
    pub queue_cap: usize,
    /// Optional deadline jitter as a fraction of the budget (e.g. `0.1`
    /// spreads each deadline ±10%) — de-synchronizes fleets of periodic
    /// monitors so their cuts don't stampede. `0.0` disables it.
    pub budget_jitter: f64,
    /// Seed for the jitter RNG; batching decisions are reproducible from
    /// (submission order, clock, seed).
    pub seed: u64,
}

impl AdmissionConfig {
    pub fn new(dim: usize, max_batch: usize) -> AdmissionConfig {
        AdmissionConfig { dim, max_batch, queue_cap: 1024, budget_jitter: 0.0, seed: 0 }
    }

    pub fn with_queue_cap(mut self, cap: usize) -> AdmissionConfig {
        self.queue_cap = cap;
        self
    }

    pub fn with_jitter(mut self, frac: f64, seed: u64) -> AdmissionConfig {
        self.budget_jitter = frac;
        self.seed = seed;
        self
    }
}

/// Admission-layer errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionError {
    /// Bounded queue at capacity (only from [`AdmissionQueue::try_submit`];
    /// the blocking [`AdmissionQueue::submit`] waits instead).
    QueueFull,
    /// The queue is shutting down; the request was not admitted.
    ShuttingDown,
    /// The request was admitted but the dispatcher died before resolving
    /// it (only during teardown of the underlying cluster).
    Canceled,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull => write!(f, "admission queue full"),
            AdmissionError::ShuttingDown => write!(f, "admission queue shutting down"),
            AdmissionError::Canceled => write!(f, "request canceled during teardown"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Why the cutter dispatched a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CutReason {
    /// `max_batch` requests were pending.
    Fill,
    /// The earliest pending deadline expired.
    Deadline,
    /// Shutdown drained the residue.
    Drain,
}

/// A caller's handle to one submitted query.
#[must_use = "dropping a Ticket discards the query result"]
pub struct Ticket {
    reader: SlotReader<Result<QueryResult, AdmissionError>>,
}

impl Ticket {
    /// Block until the batch containing this request has been resolved.
    pub fn wait(self) -> Result<QueryResult, AdmissionError> {
        self.reader.wait().unwrap_or(Err(AdmissionError::Canceled))
    }
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Ticket(..)")
    }
}

/// Counter snapshot (see [`AdmissionQueue::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Requests currently pending (admitted, not yet cut).
    pub depth: usize,
    /// Maximum pending depth ever observed.
    pub high_water: usize,
    /// Total requests admitted.
    pub submitted: u64,
    /// Total requests taken into a dispatched batch.
    pub completed: u64,
    /// `try_submit` rejections due to a full queue.
    pub rejected_full: u64,
    pub cuts_fill: u64,
    pub cuts_deadline: u64,
    pub cuts_drain: u64,
}

struct Pending {
    q: Vec<f32>,
    deadline_ns: u64,
    slot: SlotWriter<Result<QueryResult, AdmissionError>>,
}

struct State {
    pending: VecDeque<Pending>,
    shutdown: bool,
    jitter_rng: Xoshiro256,
}

struct Shared {
    state: Mutex<State>,
    /// Wakes the cutter: new submission or shutdown.
    cutter_wake: Condvar,
    /// Wakes blocked submitters: a cut freed queue space (or shutdown).
    space_free: Condvar,
    clock: Arc<dyn Clock>,
    queue: Arc<QueueStats>,
    cuts: Arc<CutCounters>,
    cfg: AdmissionConfig,
}

/// The admission queue: bounded submission FIFO + deadline-aware cutter
/// thread. See the [module docs](self) for the full contract.
pub struct AdmissionQueue {
    shared: Arc<Shared>,
    cutter: Option<JoinHandle<()>>,
}

/// Effective budget in nanoseconds after jitter. Pure so tests can prove
/// reproducibility: the same seed yields the same deadline stream.
fn jittered_budget_ns(budget: Duration, jitter_frac: f64, rng: &mut Xoshiro256) -> u64 {
    let base = budget.as_nanos().min(u64::MAX as u128) as u64;
    if jitter_frac <= 0.0 {
        return base;
    }
    let f = rng.gen_f64(-jitter_frac, jitter_frac);
    let delta = (base as f64 * f) as i64;
    if delta >= 0 {
        base.saturating_add(delta as u64)
    } else {
        base.saturating_sub(delta.unsigned_abs())
    }
}

/// The cut decision — a pure function of (queue state, `max_batch`, now).
/// `None` means keep waiting. A deadline cut fires on the *earliest*
/// deadline among pending requests (not merely the FIFO front: a tight
/// budget submitted behind a loose one must still be honored); since
/// `pending < max_batch` whenever a deadline cut fires, it takes the
/// whole queue and the urgent request always rides the cut it triggered.
fn take_cut(st: &mut State, max_batch: usize, now_ns: u64) -> Option<(Vec<Pending>, CutReason)> {
    if st.pending.is_empty() {
        return None;
    }
    // The deadline scan is only paid on the not-full path, where
    // `pending < max_batch` bounds it; a fill cut never reads deadlines.
    let reason = if st.pending.len() >= max_batch {
        CutReason::Fill
    } else if st.shutdown {
        CutReason::Drain
    } else if st.pending.iter().map(|p| p.deadline_ns).min().unwrap() <= now_ns {
        CutReason::Deadline
    } else {
        return None;
    };
    let n = st.pending.len().min(max_batch);
    Some((st.pending.drain(..n).collect(), reason))
}

impl AdmissionQueue {
    /// Start the queue with the production clock. `dispatch` resolves one
    /// flat row-major block (`nq × dim` floats, plus the remaining budget
    /// in µs of the batch's most urgent request, saturating to 0 once the
    /// deadline has passed) and returns exactly `nq` results in order.
    pub fn start<D>(cfg: AdmissionConfig, dispatch: D) -> AdmissionQueue
    where
        D: FnMut(Vec<f32>, usize, u64) -> Vec<QueryResult> + Send + 'static,
    {
        AdmissionQueue::start_with_clock(cfg, dispatch, Arc::new(SystemClock::new()))
    }

    /// Start with an injected [`Clock`] (tests use [`MockClock`]).
    pub fn start_with_clock<D>(
        cfg: AdmissionConfig,
        mut dispatch: D,
        clock: Arc<dyn Clock>,
    ) -> AdmissionQueue
    where
        D: FnMut(Vec<f32>, usize, u64) -> Vec<QueryResult> + Send + 'static,
    {
        assert!(cfg.dim > 0, "admission dim must be positive");
        assert!(cfg.max_batch > 0, "max_batch must be positive");
        assert!(cfg.queue_cap > 0, "queue_cap must be positive");
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                pending: VecDeque::with_capacity(cfg.queue_cap.min(4096)),
                shutdown: false,
                jitter_rng: Xoshiro256::seed_from_u64(cfg.seed),
            }),
            cutter_wake: Condvar::new(),
            space_free: Condvar::new(),
            clock,
            queue: Arc::new(QueueStats::new()),
            cuts: Arc::new(CutCounters::new()),
            cfg,
        });
        let shared_c = Arc::clone(&shared);
        let cutter = std::thread::Builder::new()
            .name("admission-cutter".into())
            .spawn(move || {
                let shared = shared_c;
                let max_batch = shared.cfg.max_batch;
                loop {
                    // Phase 1 (locked): wait for a cut to become due.
                    let cut = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            let now = shared.clock.now_ns();
                            if let Some(c) = take_cut(&mut st, max_batch, now) {
                                break Some((c, now));
                            }
                            if st.shutdown {
                                // take_cut drains any residue before this
                                // arm can be reached.
                                debug_assert!(st.pending.is_empty());
                                break None;
                            }
                            match st.pending.iter().map(|p| p.deadline_ns).min() {
                                None => st = shared.cutter_wake.wait(st).unwrap(),
                                Some(dl) => {
                                    // dl > now, else take_cut would have
                                    // deadline-cut above.
                                    let wait = Duration::from_nanos(dl - now);
                                    let (g, _) =
                                        shared.cutter_wake.wait_timeout(st, wait).unwrap();
                                    st = g;
                                }
                            }
                        }
                    };
                    let Some(((batch, reason), now)) = cut else { return };
                    shared.queue.on_dequeue(batch.len());
                    shared.space_free.notify_all();
                    match reason {
                        CutReason::Fill => shared.cuts.record_fill(),
                        CutReason::Deadline => shared.cuts.record_deadline(),
                        CutReason::Drain => shared.cuts.record_drain(),
                    }

                    // Phase 2 (unlocked): flatten, dispatch, fulfill.
                    let nq = batch.len();
                    let budget_us = batch
                        .iter()
                        .map(|p| p.deadline_ns)
                        .min()
                        .map(|dl| dl.saturating_sub(now) / 1_000)
                        .unwrap_or(0);
                    let mut flat = Vec::with_capacity(nq * shared.cfg.dim);
                    for p in &batch {
                        flat.extend_from_slice(&p.q);
                    }
                    let results = dispatch(flat, nq, budget_us);
                    if results.len() == nq {
                        for (p, r) in batch.into_iter().zip(results) {
                            p.slot.fulfill(Ok(r));
                        }
                    } else {
                        // Dispatcher died (cluster teardown): fail the
                        // whole batch rather than misalign replies.
                        for p in batch {
                            p.slot.fulfill(Err(AdmissionError::Canceled));
                        }
                    }
                }
            })
            .expect("spawn admission cutter");
        AdmissionQueue { shared, cutter: Some(cutter) }
    }

    /// Admit one query with a latency budget, blocking while the queue is
    /// at capacity. The deadline is `now + budget` (± configured jitter).
    pub fn submit(&self, q: &[f32], budget: Duration) -> Result<Ticket, AdmissionError> {
        self.submit_inner(q, budget, true)
    }

    /// Non-blocking admission: `Err(QueueFull)` instead of waiting.
    pub fn try_submit(&self, q: &[f32], budget: Duration) -> Result<Ticket, AdmissionError> {
        self.submit_inner(q, budget, false)
    }

    fn submit_inner(
        &self,
        q: &[f32],
        budget: Duration,
        block: bool,
    ) -> Result<Ticket, AdmissionError> {
        assert_eq!(q.len(), self.shared.cfg.dim, "query dimension mismatch");
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if st.shutdown {
                return Err(AdmissionError::ShuttingDown);
            }
            if st.pending.len() < self.shared.cfg.queue_cap {
                break;
            }
            if !block {
                self.shared.queue.on_reject();
                return Err(AdmissionError::QueueFull);
            }
            st = self.shared.space_free.wait(st).unwrap();
        }
        let now = self.shared.clock.now_ns();
        let eff = jittered_budget_ns(budget, self.shared.cfg.budget_jitter, &mut st.jitter_rng);
        let deadline_ns = now.saturating_add(eff);
        let (writer, reader) = completion_slot();
        st.pending.push_back(Pending { q: q.to_vec(), deadline_ns, slot: writer });
        self.shared.queue.on_enqueue(1);
        drop(st);
        self.shared.cutter_wake.notify_one();
        Ok(Ticket { reader })
    }

    /// Counter snapshot: queue depth + cut-reason mix.
    pub fn stats(&self) -> AdmissionStats {
        AdmissionStats {
            depth: self.shared.queue.depth(),
            high_water: self.shared.queue.high_water(),
            submitted: self.shared.queue.enqueued(),
            completed: self.shared.queue.dequeued(),
            rejected_full: self.shared.queue.rejected(),
            cuts_fill: self.shared.cuts.fill(),
            cuts_deadline: self.shared.cuts.deadline(),
            cuts_drain: self.shared.cuts.drain(),
        }
    }

    /// Live queue gauges (shared handle; survives the queue, so tests and
    /// dashboards can inspect the final state after shutdown).
    pub fn queue_stats(&self) -> Arc<QueueStats> {
        Arc::clone(&self.shared.queue)
    }

    /// Live cut-reason counters (shared handle, see [`queue_stats`]).
    ///
    /// [`queue_stats`]: AdmissionQueue::queue_stats
    pub fn cut_counters(&self) -> Arc<CutCounters> {
        Arc::clone(&self.shared.cuts)
    }
}

impl Drop for AdmissionQueue {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        // Wake everyone: the cutter to drain, blocked submitters to bail.
        self.shared.cutter_wake.notify_all();
        self.shared.space_free.notify_all();
        if let Some(j) = self.cutter.take() {
            let _ = j.join();
        }
    }
}

/// Build the dispatcher closure that ships a cut to an Orchestrator root
/// channel and waits for the reduced results (one reply per query, in
/// order). Lives here so [`Orchestrator::enable_admission`] stays a
/// two-liner.
///
/// [`Orchestrator::enable_admission`]: crate::coordinator::Orchestrator::enable_admission
pub(crate) fn root_dispatcher(
    root_tx: Sender<crate::coordinator::orchestrator::RootRequest>,
) -> impl FnMut(Vec<f32>, usize, u64) -> Vec<QueryResult> + Send + 'static {
    use crate::coordinator::orchestrator::RootRequest;
    move |qs: Vec<f32>, nq: usize, budget_us: u64| -> Vec<QueryResult> {
        let (tx, rx) = channel();
        if root_tx.send(RootRequest::Batch { qs, nq, budget_us, reply_to: tx }).is_err() {
            return Vec::new();
        }
        rx.recv().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(deadline_ns: u64) -> Pending {
        let (writer, _reader) = completion_slot();
        Pending { q: vec![0.0], deadline_ns, slot: writer }
    }

    fn state(deadlines: &[u64], shutdown: bool) -> State {
        State {
            pending: deadlines.iter().map(|&d| pending(d)).collect(),
            shutdown,
            jitter_rng: Xoshiro256::seed_from_u64(0),
        }
    }

    /// Fake dispatcher that echoes each query's first coordinate back in
    /// `positive_share` — proves result↔caller alignment end to end.
    fn echo(flat: Vec<f32>, nq: usize, _budget_us: u64) -> Vec<QueryResult> {
        let dim = if nq == 0 { 0 } else { flat.len() / nq };
        (0..nq)
            .map(|i| QueryResult {
                qid: i as u64,
                neighbors: Vec::new(),
                positive_share: flat[i * dim] as f64,
                prediction: false,
                max_comparisons: 0,
                per_node_comparisons: Vec::new(),
                latency_s: 0.0,
            })
            .collect()
    }

    // -- table-driven cut decisions (pure, MockClock-style time values) --

    #[test]
    fn cut_decision_table() {
        // (deadlines, shutdown, max_batch, now) -> expected (len, reason).
        let cases: &[(&[u64], bool, usize, u64, Option<(usize, CutReason)>)] = &[
            // Empty queue never cuts, even under shutdown.
            (&[], false, 4, 0, None),
            (&[], true, 4, 0, None),
            // (a) A full batch cuts immediately, no matter the deadlines.
            (&[1000, 1000, 1000, 1000], false, 4, 0, Some((4, CutReason::Fill))),
            // Overfull queue cuts max_batch, leaving the rest.
            (&[1000; 6], false, 4, 0, Some((4, CutReason::Fill))),
            // Fill wins over an expired deadline (it is the cheaper cut
            // and the expired request rides it anyway).
            (&[0, 1000, 1000, 1000], false, 4, 500, Some((4, CutReason::Fill))),
            // (b) A lone request cuts exactly at its deadline: one tick
            // before -> wait; at the deadline -> cut.
            (&[1000], false, 4, 999, None),
            (&[1000], false, 4, 1000, Some((1, CutReason::Deadline))),
            (&[1000], false, 4, 1001, Some((1, CutReason::Deadline))),
            // The EARLIEST deadline fires the cut, not the FIFO front:
            // a tight budget submitted behind a loose one is honored.
            (&[5000, 1000], false, 4, 1000, Some((2, CutReason::Deadline))),
            (&[5000, 1000], false, 4, 999, None),
            // (d) Shutdown drains a short batch without waiting for the
            // deadline.
            (&[1_000_000], true, 4, 0, Some((1, CutReason::Drain))),
            (&[1_000_000; 3], true, 4, 0, Some((3, CutReason::Drain))),
            // Shutdown with a full queue still counts as a fill cut.
            (&[1_000_000; 4], true, 4, 0, Some((4, CutReason::Fill))),
        ];
        for (i, (deadlines, shutdown, max_batch, now, want)) in cases.iter().enumerate() {
            let mut st = state(deadlines, *shutdown);
            let got = take_cut(&mut st, *max_batch, *now);
            match (got, want) {
                (None, None) => {}
                (Some((batch, reason)), Some((want_len, want_reason))) => {
                    assert_eq!(batch.len(), *want_len, "case {i}: cut size");
                    assert_eq!(reason, *want_reason, "case {i}: cut reason");
                    // FIFO order is preserved within the cut.
                    assert_eq!(
                        st.pending.len(),
                        deadlines.len() - want_len,
                        "case {i}: residue"
                    );
                }
                (got, want) => panic!("case {i}: got {got:?} want {want:?}", got = got.map(|(b, r)| (b.len(), r)), want = want),
            }
        }
    }

    #[test]
    fn deadline_cut_is_exact_over_mock_time_sweep() {
        // (b) again, as a sweep: walking MockClock time one nanosecond at
        // a time across the deadline flips the decision exactly once.
        let clock = MockClock::new(0);
        let deadline = 4242u64;
        for t in deadline.saturating_sub(3)..deadline + 3 {
            clock.set_ns(t);
            let mut st = state(&[deadline], false);
            let cut = take_cut(&mut st, 16, clock.now_ns());
            assert_eq!(cut.is_some(), t >= deadline, "t={t}");
        }
    }

    #[test]
    fn jittered_deadlines_are_reproducible_from_seed() {
        let budget = Duration::from_millis(10);
        let mut a = Xoshiro256::seed_from_u64(99);
        let mut b = Xoshiro256::seed_from_u64(99);
        let sa: Vec<u64> = (0..32).map(|_| jittered_budget_ns(budget, 0.25, &mut a)).collect();
        let sb: Vec<u64> = (0..32).map(|_| jittered_budget_ns(budget, 0.25, &mut b)).collect();
        assert_eq!(sa, sb, "same seed must give the same deadline stream");
        let base = budget.as_nanos() as u64;
        assert!(sa.iter().any(|&x| x != base), "jitter must actually perturb");
        for &x in &sa {
            let lo = (base as f64 * 0.75) as u64;
            let hi = (base as f64 * 1.25) as u64;
            assert!((lo..=hi).contains(&x), "jitter out of band: {x}");
        }
        // Zero jitter is the identity.
        let mut c = Xoshiro256::seed_from_u64(99);
        assert_eq!(jittered_budget_ns(budget, 0.0, &mut c), base);
    }

    // -- threaded queue behavior (MockClock frozen: no timing assumptions) --

    /// Budgets far enough out that a frozen MockClock can never expire
    /// them — every observable cut in these tests is Fill or Drain.
    const FAR: Duration = Duration::from_secs(3600);

    #[test]
    fn backpressure_blocks_instead_of_dropping() {
        // (c): cap 2, max_batch 2, dispatcher gated so the queue refills
        // while the cutter is stuck. All synchronization is via channel
        // handshakes — no sleeps.
        let (evt_tx, evt_rx) = channel::<usize>();
        let (gate_tx, gate_rx) = channel::<()>();
        let dispatch = move |flat: Vec<f32>, nq: usize, b: u64| {
            evt_tx.send(nq).unwrap();
            gate_rx.recv().unwrap();
            echo(flat, nq, b)
        };
        let cfg = AdmissionConfig::new(1, 2).with_queue_cap(2);
        let q = AdmissionQueue::start_with_clock(cfg, dispatch, Arc::new(MockClock::new(0)));

        let t1 = q.submit(&[1.0], FAR).unwrap();
        let t2 = q.submit(&[2.0], FAR).unwrap();
        // The cutter fill-cuts {1,2} and blocks inside the dispatcher.
        assert_eq!(evt_rx.recv().unwrap(), 2);
        let t3 = q.submit(&[3.0], FAR).unwrap();
        let t4 = q.submit(&[4.0], FAR).unwrap();
        // Queue at capacity and the cutter is gated: non-blocking
        // admission must report backpressure, not drop.
        assert!(matches!(q.try_submit(&[5.0], FAR), Err(AdmissionError::QueueFull)));
        assert_eq!(q.stats().rejected_full, 1);

        // A blocking submit parks until a cut frees a slot.
        let q_ref = &q;
        let t5 = std::thread::scope(|s| {
            let blocked = s.spawn(move || q_ref.submit(&[5.0], FAR).unwrap());
            gate_tx.send(()).unwrap(); // release {1,2}
            assert_eq!(evt_rx.recv().unwrap(), 2); // cutter took {3,4}
            gate_tx.send(()).unwrap(); // release {3,4}
            let t5 = blocked.join().unwrap();
            gate_tx.send(()).unwrap(); // pre-arm the gate for the drain cut
            t5
        });
        drop(q); // drains {5}

        // Every admitted request resolved, in alignment with its payload.
        for (t, want) in [(t1, 1.0), (t2, 2.0), (t3, 3.0), (t4, 4.0), (t5, 5.0)] {
            assert_eq!(t.wait().unwrap().positive_share, want);
        }
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        // (d): frozen clock + far deadlines + short queue means nothing
        // can cut before shutdown; dropping the queue must still resolve
        // every ticket via drain cuts.
        let cfg = AdmissionConfig::new(1, 100).with_queue_cap(100);
        let q = AdmissionQueue::start_with_clock(cfg, echo, Arc::new(MockClock::new(0)));
        let queue_stats = q.queue_stats();
        let cut_counters = q.cut_counters();
        let tickets: Vec<Ticket> =
            (0..5).map(|i| q.submit(&[i as f32], FAR).unwrap()).collect();
        drop(q);
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().positive_share, i as f64, "drain order");
        }
        assert_eq!(queue_stats.enqueued(), 5);
        assert_eq!(queue_stats.dequeued(), 5);
        assert_eq!(queue_stats.depth(), 0);
        assert!(cut_counters.drain() >= 1, "drain cut must be recorded");
        assert_eq!(cut_counters.deadline(), 0, "frozen clock cannot deadline-cut");
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let cfg = AdmissionConfig::new(1, 4);
        let q = AdmissionQueue::start_with_clock(cfg, echo, Arc::new(MockClock::new(0)));
        // Force the shutdown flag the way Drop does, then observe submit.
        q.shared.state.lock().unwrap().shutdown = true;
        q.shared.cutter_wake.notify_all();
        assert_eq!(q.submit(&[0.0], FAR).unwrap_err(), AdmissionError::ShuttingDown);
        assert_eq!(q.try_submit(&[0.0], FAR).unwrap_err(), AdmissionError::ShuttingDown);
    }

    #[test]
    fn zero_budget_requests_all_complete_with_deadline_cuts() {
        // Real clock, budget 0: every request's deadline is already due,
        // so each cut is a deadline cut (max_batch too large to fill).
        // Assertions are about values and counters, never about timing.
        let cfg = AdmissionConfig::new(2, 64);
        let q = AdmissionQueue::start(cfg, echo);
        let tickets: Vec<Ticket> = (0..8)
            .map(|i| q.submit(&[i as f32, 0.5], Duration::ZERO).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().positive_share, i as f64);
        }
        let st = q.stats();
        assert_eq!(st.submitted, 8);
        assert_eq!(st.completed, 8);
        assert_eq!(st.cuts_fill, 0, "64-wide batches cannot fill with 8 requests");
        assert!(st.cuts_deadline >= 1);
    }

    // -- completion slot --

    #[test]
    fn completion_slot_basic_paths() {
        // Fulfill before wait.
        let (w, r) = completion_slot();
        w.fulfill(7u32);
        assert_eq!(r.wait(), Some(7));
        // Drop before wait.
        let (w, r) = completion_slot::<u32>();
        drop(w);
        assert_eq!(r.wait(), None);
        // Drop the reader first: fulfilling must not panic or leak waiters.
        let (w, r) = completion_slot();
        drop(r);
        w.fulfill(9u32);
    }

    #[test]
    fn completion_slot_handoff_stress() {
        // 100 iterations of a racing producer/consumer pair (loom-style
        // schedule exploration with plain threads): whichever side wins
        // the race, the value must arrive exactly once.
        for round in 0..100u64 {
            let (w, r) = completion_slot();
            let producer = std::thread::spawn(move || w.fulfill(round * 7 + 1));
            let consumer = std::thread::spawn(move || r.wait());
            producer.join().unwrap();
            assert_eq!(consumer.join().unwrap(), Some(round * 7 + 1), "round {round}");
        }
        // Same race against a writer that drops instead of fulfilling.
        for round in 0..100u64 {
            let (w, r) = completion_slot::<u64>();
            let consumer = std::thread::spawn(move || r.wait());
            let producer = std::thread::spawn(move || drop(w));
            producer.join().unwrap();
            assert_eq!(consumer.join().unwrap(), None, "round {round}");
        }
    }
}
